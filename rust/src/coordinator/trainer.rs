//! The distributed trainer: the leader state machine, generic over the
//! cluster [`Backend`] the map rounds run on (in-process threads or
//! real TCP worker processes — `cluster`).

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use crate::cluster::wire::{self, Request, Response};
use crate::cluster::{Backend, PoolBackend, WorkerReply};
use crate::gp::params::{GlobalGrads, GlobalParams};
use crate::gp::{self, kernel, MathMode, Stats};
use crate::linalg::Matrix;
use crate::obs;
use crate::optim::{Adam, Scg};
use crate::runtime::{ArtifactConfig, Manifest, ShardData};
use crate::store::{DataSource, RowMapper};
use crate::telemetry::{IterationLog, RoundTiming, RunLog};
use crate::util::rng::Rng;

/// Which of the paper's two models is being fit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// Sparse GP regression (Titsias 2009): inputs observed, q(X) a delta.
    Regression,
    /// Bayesian GPLVM (Titsias & Lawrence 2010): latent inputs, local
    /// variational parameters (mu_i, s_i) optimised on the workers.
    Lvm,
}

/// Optimiser for the global parameters.
#[derive(Debug, Clone, Copy)]
pub enum GlobalOpt {
    /// Scaled conjugate gradients (the paper's optimiser).
    Scg,
    /// Adam ablation (DESIGN.md ablation index).
    Adam { lr: f64 },
}

/// Training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Artifact config name in `artifacts/manifest.json`.
    pub artifact: String,
    /// Artifacts directory.
    pub artifacts_dir: PathBuf,
    /// Number of worker nodes (threads or processes).
    pub workers: usize,
    pub model: ModelKind,
    pub global_opt: GlobalOpt,
    /// Adam learning rate for the workers' local q(X) updates.
    pub local_lr: f64,
    /// Kmm jitter.
    pub jitter: f64,
    /// Per-iteration, per-node failure probability (paper Fig. 7).
    pub failure_rate: f64,
    /// Floor on the local variances (keeps log s finite).
    pub min_xvar: f64,
    /// Minimum seconds between backend liveness probes at `step()`
    /// start. Map rounds already detect mid-round deaths; the periodic
    /// heartbeat only catches nodes that died while the leader was
    /// otherwise idle, so it is rate-limited off the per-iteration
    /// critical path (0 = probe every step).
    pub heartbeat_secs: f64,
    /// Let workers reuse psi intermediates across the two map rounds of
    /// one evaluation (keyed by the per-evaluation parameter version).
    /// `false` forces a fresh recompute every round — bit-identical
    /// traces either way (tested), only slower.
    pub psi_cache: bool,
    /// Numerical execution policy for the whole cluster: `Strict`
    /// (default) keeps traces bit-for-bit with the reference, `Fast`
    /// runs the reciprocal/batched-exp kernels (within 1e-9 relative of
    /// Strict, DESIGN.md §8). Carried to every worker in the wire v3
    /// `Init`; requires `psi_cache` (validated at bring-up).
    pub math_mode: MathMode,
    /// Intra-worker psi-fill parallelism (>= 1): each worker splits its
    /// psi1/psi2 fills over this many threads using fixed row ranges
    /// computed from shard size and thread count only, so every value
    /// is bit-identical (DESIGN.md §11). Carried to every worker in the
    /// wire v7 `Init`; workers pinned via `--fill-threads` reject a
    /// mismatch at bring-up.
    pub fill_threads: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact: "small".into(),
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            workers: 4,
            model: ModelKind::Regression,
            global_opt: GlobalOpt::Scg,
            local_lr: 0.05,
            jitter: 1e-6,
            failure_rate: 0.0,
            min_xvar: 1e-6,
            heartbeat_secs: 5.0,
            psi_cache: true,
            math_mode: MathMode::Strict,
            fill_threads: 1,
            seed: 0,
        }
    }
}

/// Build the per-worker `Init` messages (shapes + model flags + shard)
/// that initialise a cluster backend; `shards[k]` becomes worker `k`.
pub fn make_inits(
    cfg: &TrainConfig,
    art: &ArtifactConfig,
    shards: Vec<ShardData>,
) -> Vec<wire::Init> {
    shards
        .into_iter()
        .map(|shard| wire::Init {
            artifact: art.clone(),
            lvm: cfg.model == ModelKind::Lvm,
            local_lr: cfg.local_lr,
            min_xvar: cfg.min_xvar,
            psi_cache: cfg.psi_cache,
            math_mode: cfg.math_mode,
            fill_threads: cfg.fill_threads.max(1) as u32,
            shard,
            shard_ref: None,
        })
        .collect()
}

/// Out-of-core bring-up input (DESIGN.md §13): a [`DataSource`] the
/// leader streams chunk-by-chunk plus the [`RowMapper`] that turns
/// each raw chunk into `(Xmu, Xvar, Y)` rows. The leader never holds
/// more than one `chunk_rows`-row chunk of the dataset — peak leader
/// memory is bounded by the chunk size, not n.
pub struct StreamConfig<'a> {
    pub source: &'a dyn DataSource,
    pub mapper: &'a dyn RowMapper,
    /// Rows per streamed chunk (>= 1); the leader's memory bound.
    pub chunk_rows: usize,
    /// KL annealing weight applied to every worker's shard.
    pub kl_weight: f64,
    /// Worker-local shard load (wire v9): when `Some`, worker `k`
    /// reads `shard_refs[k]` from its own disk and verifies the
    /// checksum — no data rows cross the wire at all. The refs must
    /// cover exactly the contiguous partition this bring-up computes
    /// (one store shard per worker); regression-only.
    pub shard_refs: Option<Vec<wire::ShardRef>>,
}

/// The distributed trainer (leader).
pub struct Trainer<B: Backend = PoolBackend> {
    backend: B,
    pub params: GlobalParams,
    cfg: TrainConfig,
    dout: usize,
    pub log: RunLog,
    rng: Rng,
    scg: Option<Scg>,
    adam: Option<Adam>,
    /// workers participating in the current iteration's map rounds
    alive: Vec<bool>,
    /// permanently out-of-rotation workers: decommissioned, or their
    /// backend connection died (drop-the-partial-term forever, §5.2)
    dead: Vec<bool>,
    /// subset of `dead` whose shard data is GONE (connection died
    /// before the shard could be fetched back) — unlike decommission,
    /// which re-shards onto the survivors first
    lost: Vec<bool>,
    /// scratch: rounds recorded during the current iteration
    rounds: Vec<RoundTiming>,
    central_secs: f64,
    /// apply local updates on the next gradient round
    update_locals_next: bool,
    last_f: f64,
    /// the objective changed since SCG last anchored (locals moved or a
    /// node failed) — a refresh evaluation is needed before stepping
    objective_dirty: bool,
    /// workers whose backend connection died during this iteration
    newly_failed: Vec<usize>,
    /// when the backend was last liveness-probed (rate limiting)
    last_heartbeat: Option<Instant>,
    /// monotone parameter-version counter: bumped once per evaluation,
    /// tagged onto both map rounds so workers can reuse round-1 psi
    /// intermediates in round 2 without ever aliasing a stale cache
    eval_version: u64,
    /// posterior weights at the current parameters. Event-invalidated:
    /// everything that moves the objective under the leader — a step
    /// (params), local q(X) updates, re-sharding, node death — clears
    /// it, so repeated `predict`/`posterior`/`export_model` calls at
    /// fixed parameters cost ZERO extra cluster rounds.
    posterior_cache: Option<gp::PosteriorWeights>,
    /// posterior requests served from the cache (observability/tests)
    posterior_hits: u64,
    /// original dataset row indices each worker's shard currently
    /// holds, in shard order. `Some` for every sharded bring-up
    /// (contiguous partition); kept exact across `decommission`
    /// re-sharding (moved rows land at the survivors' tails). `None`
    /// only for `with_backend` bring-ups, reconstructed lazily from a
    /// gather round (valid while the order is still the contiguous
    /// dataset order).
    row_ids: Option<Vec<Vec<usize>>>,
    /// iterations completed before this trainer's `RunLog` started
    /// (restored from a checkpoint); exports and fresh checkpoints
    /// report cumulative counts so a `--resume --iters 0 --export`
    /// re-export keeps the original provenance.
    resumed_iterations: u64,
    /// bound F at the restored checkpoint (NaN when starting fresh) —
    /// the export provenance fallback while no new iteration has run.
    resumed_bound: f64,
    /// Live trainer metrics (DESIGN.md §10): round latency histograms,
    /// dropped-worker counts, per-worker heartbeat ages.
    metrics: obs::Registry,
}

impl Trainer<PoolBackend> {
    /// Spawn an in-process cluster (one worker thread per shard).
    /// `shards[k]` becomes worker k's slice; local parameters
    /// (Xmu, Xvar) live only on the workers from here on.
    pub fn new(
        cfg: TrainConfig,
        params: GlobalParams,
        shards: Vec<ShardData>,
    ) -> Result<Trainer<PoolBackend>> {
        let dir = cfg.artifacts_dir.clone();
        build_with(cfg, params, shards, |inits| PoolBackend::new(inits, dir))
    }

    /// Out-of-core in-process bring-up: stream the shards from a
    /// [`DataSource`] chunk-by-chunk instead of materialising them
    /// (DESIGN.md §13). Strict-mode traces are bit-identical to
    /// [`Trainer::new`] over the same rows.
    pub fn new_streaming(
        cfg: TrainConfig,
        params: GlobalParams,
        stream: &StreamConfig<'_>,
    ) -> Result<Trainer<PoolBackend>> {
        let dir = cfg.artifacts_dir.clone();
        build_streaming(cfg, params, stream, |inits| PoolBackend::new(inits, dir))
    }
}

impl Trainer<crate::cluster::TcpBackend> {
    /// Leader bring-up over TCP, accept direction: validate shapes
    /// FIRST (before any shard crosses the wire), then accept
    /// `cfg.workers` worker connections on `listener` and ship each
    /// its shard. Startup time (handshakes + shard shipping + remote
    /// node construction) lands in `log.startup_secs`.
    pub fn accept_tcp(
        cfg: TrainConfig,
        params: GlobalParams,
        shards: Vec<ShardData>,
        listener: &std::net::TcpListener,
    ) -> Result<Trainer<crate::cluster::TcpBackend>> {
        build_with(cfg, params, shards, |inits| {
            crate::cluster::TcpBackend::accept(listener, inits)
        })
    }

    /// Leader bring-up over TCP, dial direction: like [`Self::accept_tcp`]
    /// but connecting out to workers already listening (`worker --listen`);
    /// `addrs[k]` becomes worker `k`.
    pub fn connect_tcp(
        cfg: TrainConfig,
        params: GlobalParams,
        shards: Vec<ShardData>,
        addrs: &[String],
    ) -> Result<Trainer<crate::cluster::TcpBackend>> {
        build_with(cfg, params, shards, |inits| {
            crate::cluster::TcpBackend::connect(addrs, inits)
        })
    }

    /// Out-of-core TCP bring-up, accept direction: workers are
    /// initialised with empty shards (or a v9 `shard_ref` each), then
    /// — unless the refs made shipping unnecessary — the leader streams
    /// each worker's rows in `chunk_rows`-sized parts. Leader peak
    /// memory is bounded by the chunk size, not n (DESIGN.md §13).
    pub fn accept_tcp_streaming(
        cfg: TrainConfig,
        params: GlobalParams,
        stream: &StreamConfig<'_>,
        listener: &std::net::TcpListener,
    ) -> Result<Trainer<crate::cluster::TcpBackend>> {
        build_streaming(cfg, params, stream, |inits| {
            crate::cluster::TcpBackend::accept(listener, inits)
        })
    }

    /// Out-of-core TCP bring-up, dial direction (see
    /// [`Self::accept_tcp_streaming`]); `addrs[k]` becomes worker `k`.
    pub fn connect_tcp_streaming(
        cfg: TrainConfig,
        params: GlobalParams,
        stream: &StreamConfig<'_>,
        addrs: &[String],
    ) -> Result<Trainer<crate::cluster::TcpBackend>> {
        build_streaming(cfg, params, stream, |inits| {
            crate::cluster::TcpBackend::connect(addrs, inits)
        })
    }
}

/// Shared constructor body for every sharded bring-up: validate that
/// shards match workers and that the parameter shapes match the
/// artifact BEFORE any backend exists (or any shard crosses a wire),
/// then time the backend construction into `log.startup_secs`.
fn build_with<B: Backend>(
    cfg: TrainConfig,
    params: GlobalParams,
    shards: Vec<ShardData>,
    make_backend: impl FnOnce(Vec<wire::Init>) -> Result<B>,
) -> Result<Trainer<B>> {
    ensure!(
        shards.len() == cfg.workers,
        "need exactly one shard per worker ({} vs {})",
        shards.len(),
        cfg.workers
    );
    let art = load_checked_artifact(&cfg, &params)?;
    let dout = art.d;
    // shard k holds the contiguous dataset rows [offset_k, offset_k +
    // len_k) — record them so gathers stay addressable after re-sharding
    let mut row_ids = Vec::with_capacity(shards.len());
    let mut offset = 0usize;
    for shard in &shards {
        row_ids.push((offset..offset + shard.len()).collect());
        offset += shard.len();
    }
    let inits = make_inits(&cfg, &art, shards);
    let t0 = Instant::now();
    let backend = make_backend(inits)?;
    let startup_secs = t0.elapsed().as_secs_f64();
    let mut t = Trainer::from_parts(cfg, params, backend, dout, Some(row_ids));
    t.log.startup_secs = startup_secs;
    Ok(t)
}

/// Shared constructor body for the out-of-core bring-ups (DESIGN.md
/// §13): validate shapes against the artifact FIRST, build every
/// worker's `Init` with an EMPTY shard (zero rows, correct widths), so
/// backend construction ships no data — then stream each worker's
/// contiguous partition in `chunk_rows`-sized `AppendShard` parts (or
/// skip shipping entirely when v9 `shard_refs` let the workers load
/// their own store shards). `AppendShard` rebuilds worker optimiser
/// state from zero at each append, so after bring-up every worker is
/// in exactly the state a materialised `build_with` would have put it
/// in — strict-mode traces are bit-identical (tested in
/// `tests/store.rs`). Startup time (backend construction + the whole
/// stream) lands in `log.startup_secs`.
fn build_streaming<B: Backend>(
    cfg: TrainConfig,
    params: GlobalParams,
    stream: &StreamConfig<'_>,
    make_backend: impl FnOnce(Vec<wire::Init>) -> Result<B>,
) -> Result<Trainer<B>> {
    let art = load_checked_artifact(&cfg, &params)?;
    let dout = art.d;
    ensure!(cfg.workers >= 1, "need at least one worker");
    ensure!(stream.chunk_rows >= 1, "chunk_rows must be >= 1");
    let n = stream.source.rows();
    ensure!(
        n >= cfg.workers,
        "streaming bring-up needs at least one row per worker ({} rows, {} workers)",
        n,
        cfg.workers
    );
    let (q, d) = stream.mapper.shapes(stream.source.dims())?;
    ensure!(
        q == art.q && d == art.d,
        "mapped shapes (q={}, d={}) do not match artifact {} (q={}, d={})",
        q,
        d,
        cfg.artifact,
        art.q,
        art.d
    );

    // the same contiguous near-equal split `partition` produces — the
    // bit-identity contract with the materialised bring-up
    let base = n / cfg.workers;
    let extra = n % cfg.workers;
    let mut ranges = Vec::with_capacity(cfg.workers);
    let mut offset = 0usize;
    for k in 0..cfg.workers {
        let len = base + usize::from(k < extra);
        ranges.push((offset, offset + len));
        offset += len;
    }

    if let Some(refs) = &stream.shard_refs {
        ensure!(
            refs.len() == cfg.workers,
            "need exactly one shard_ref per worker ({} vs {})",
            refs.len(),
            cfg.workers
        );
        ensure!(
            cfg.model == ModelKind::Regression,
            "shard_ref bring-up is regression-only: LVM latents are leader-derived and \
             must ship over the wire"
        );
        for (k, r) in refs.iter().enumerate() {
            let want = ranges[k].1 - ranges[k].0;
            ensure!(
                r.rows as usize == want,
                "shard_ref {} covers {} rows but worker {}'s partition is {} — store \
                 shards must align 1:1 with the worker partition",
                k,
                r.rows,
                k,
                want
            );
        }
    }

    let row_ids: Vec<Vec<usize>> = ranges.iter().map(|&(s, e)| (s..e).collect()).collect();
    let t0 = Instant::now();
    let inits: Vec<wire::Init> = (0..cfg.workers)
        .map(|k| {
            let empty = ShardData {
                xmu: Matrix::zeros(0, q),
                xvar: Matrix::zeros(0, q),
                y: Matrix::zeros(0, d),
                kl_weight: stream.kl_weight,
            };
            let mut init = make_inits(&cfg, &art, vec![empty]).pop().expect("one init");
            init.shard_ref = stream.shard_refs.as_ref().map(|refs| refs[k].clone());
            init
        })
        .collect();
    let mut backend = make_backend(inits)?;
    if stream.shard_refs.is_none() {
        for (k, &(start, end)) in ranges.iter().enumerate() {
            stream
                .source
                .stream_range(start, end, stream.chunk_rows, &mut |row0, chunk| {
                    let (xmu, xvar, y) = stream.mapper.map(row0, chunk)?;
                    ensure!(
                        xmu.cols() == q && y.cols() == d,
                        "mapper produced (q={}, d={}) at row {}, expected (q={}, d={})",
                        xmu.cols(),
                        y.cols(),
                        row0,
                        q,
                        d
                    );
                    let part = ShardData {
                        xmu,
                        xvar,
                        y,
                        kl_weight: stream.kl_weight,
                    };
                    let reply = backend
                        .map_one(k, &Request::AppendShard { part })
                        .ok_or_else(|| {
                            anyhow!("worker {k} died while receiving its shard stream")
                        })?;
                    match reply.value {
                        Response::Ok => Ok(()),
                        Response::Err(e) => bail!("worker {k}: {e}"),
                        other => bail!("worker {k}: unexpected reply {other:?}"),
                    }
                })?;
        }
    }
    let startup_secs = t0.elapsed().as_secs_f64();
    let mut t = Trainer::from_parts(cfg, params, backend, dout, Some(row_ids));
    t.log.startup_secs = startup_secs;
    Ok(t)
}

/// Load the artifact configuration named by `cfg` and validate the
/// global parameter shapes against it — the single validation site
/// shared by every trainer constructor. Also rejects the one invalid
/// config combination: fast math without the psi cache (the
/// forced-fresh path is the strict reference and has no fast variant).
fn load_checked_artifact(cfg: &TrainConfig, params: &GlobalParams) -> Result<ArtifactConfig> {
    ensure!(
        cfg.psi_cache || cfg.math_mode == MathMode::Strict,
        "math mode {} requires psi_cache (psi_cache=false selects the strict \
         forced-fresh reference)",
        cfg.math_mode
    );
    ensure!(
        cfg.fill_threads >= 1,
        "fill_threads must be >= 1 (got {})",
        cfg.fill_threads
    );
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let art = manifest.config(&cfg.artifact)?;
    ensure!(
        art.m == params.m() && art.q == params.q(),
        "params shape (m={}, q={}) does not match artifact {} (m={}, q={})",
        params.m(),
        params.q(),
        cfg.artifact,
        art.m,
        art.q
    );
    Ok(art.clone())
}

impl<B: Backend> Trainer<B> {
    /// Drive an already-initialised cluster backend (e.g. a
    /// [`crate::cluster::TcpBackend`] whose worker processes received
    /// their shards during the handshake).
    pub fn with_backend(cfg: TrainConfig, params: GlobalParams, backend: B) -> Result<Trainer<B>> {
        ensure!(
            backend.workers() == cfg.workers,
            "backend has {} workers but the config expects {}",
            backend.workers(),
            cfg.workers
        );
        let art = load_checked_artifact(&cfg, &params)?;
        Ok(Self::from_parts(cfg, params, backend, art.d, None))
    }

    /// Assemble the leader state (shapes already validated).
    fn from_parts(
        cfg: TrainConfig,
        params: GlobalParams,
        backend: B,
        dout: usize,
        row_ids: Option<Vec<Vec<usize>>>,
    ) -> Trainer<B> {
        let alive = vec![true; cfg.workers];
        let dead = vec![false; cfg.workers];
        let lost = vec![false; cfg.workers];
        let rng = Rng::new(cfg.seed ^ 0xC0FFEE);
        Trainer {
            backend,
            params,
            cfg,
            dout,
            log: RunLog::default(),
            rng,
            scg: None,
            adam: None,
            alive,
            dead,
            lost,
            rounds: Vec::new(),
            central_secs: 0.0,
            update_locals_next: false,
            last_f: f64::NAN,
            objective_dirty: false,
            newly_failed: Vec::new(),
            last_heartbeat: None,
            eval_version: 0,
            posterior_cache: None,
            posterior_hits: 0,
            row_ids,
            resumed_iterations: 0,
            resumed_bound: f64::NAN,
            metrics: obs::Registry::new(),
        }
    }

    /// The trainer's live metrics registry (round latency histograms,
    /// `train.dropped_workers`, per-worker heartbeat-age gauges).
    pub fn metrics(&self) -> &obs::Registry {
        &self.metrics
    }

    /// Iterations completed in total, including any restored from a
    /// checkpoint before this trainer's own `RunLog` started.
    fn completed_iterations(&self) -> u64 {
        self.resumed_iterations + self.log.iterations.len() as u64
    }

    /// Bound F at the last completed iteration — this run's if any ran,
    /// otherwise the restored checkpoint's (NaN when neither exists).
    fn completed_bound(&self) -> f64 {
        if self.log.iterations.is_empty() {
            self.resumed_bound
        } else {
            self.log.final_bound()
        }
    }

    pub fn dout(&self) -> usize {
        self.dout
    }

    pub fn workers(&self) -> usize {
        self.cfg.workers
    }

    /// The backend driving the map rounds (telemetry inspection).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Mutable backend access (e.g. tightening TCP timeouts).
    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Adjust the per-iteration node failure probability (Fig. 7 sweeps).
    pub fn set_failure_rate(&mut self, rate: f64) {
        self.cfg.failure_rate = rate;
    }

    /// Permanently decommission worker `k`, re-sharding its data across
    /// the survivors — the paper's §5.2 *alternative* recovery strategy
    /// ("load the data to a different node and restart the calculation").
    /// The shard is fetched back from the dying worker (standing in for
    /// a replica read); the survivors' local optimiser state is rebuilt
    /// at the new shapes.
    pub fn decommission(&mut self, k: usize) -> Result<()> {
        ensure!(k < self.cfg.workers, "no such worker {k}");
        ensure!(!self.dead[k], "worker {k} already decommissioned");
        let survivors: Vec<usize> = (0..self.cfg.workers)
            .filter(|i| *i != k && !self.dead[*i])
            .collect();
        ensure!(!survivors.is_empty(), "cannot decommission the last worker");
        // the moved rows keep their original indices: learn the current
        // layout first if this trainer was built over a pre-initialised
        // backend and has never gathered
        self.ensure_row_ids()?;

        // fetch the doomed shard (replica read); the dead node keeps nothing
        let reply = self
            .backend
            .map_one(k, &Request::FetchShard { clear: true })
            .ok_or_else(|| anyhow!("worker {k} unreachable"))?;
        let orphan = match reply.value {
            Response::Shard(s) => s,
            Response::Err(e) => bail!("worker {k}: {e}"),
            other => bail!("worker {k}: unexpected reply {other:?}"),
        };

        // split the orphan shard across the survivors
        let parts = partition(
            &orphan.xmu,
            &orphan.xvar,
            &orphan.y,
            orphan.kl_weight,
            survivors.len(),
        );
        for (s, part) in survivors.iter().zip(parts) {
            let reply = self
                .backend
                .map_one(*s, &Request::AppendShard { part })
                .ok_or_else(|| anyhow!("survivor {s} unreachable"))?;
            match reply.value {
                Response::Ok => {}
                Response::Err(e) => bail!("survivor {s}: {e}"),
                other => bail!("survivor {s}: unexpected reply {other:?}"),
            }
        }
        // mirror the re-shard in the row-index map: `partition` splits
        // rows into the same contiguous chunks `split_even` produces,
        // and `AppendShard` stacks each part at its survivor's tail
        let ids = self.row_ids.as_mut().expect("ensured above");
        let orphan_ids = std::mem::take(&mut ids[k]);
        for (s, part_ids) in survivors.iter().zip(split_even(&orphan_ids, survivors.len())) {
            ids[*s].extend(part_ids);
        }
        self.dead[k] = true;
        self.objective_dirty = true;
        self.posterior_cache = None;
        Ok(())
    }

    /// Workers currently decommissioned or lost.
    pub fn dead_workers(&self) -> Vec<usize> {
        (0..self.cfg.workers).filter(|k| self.dead[*k]).collect()
    }

    /// Mark workers whose backend connection died mid-round as
    /// permanently lost (§5.2: their partial terms are dropped; over
    /// TCP the data cannot be fetched back from a dead process).
    fn absorb_backend_failures(&mut self, include: &[bool], replies: &[Option<WorkerReply>]) {
        for k in 0..include.len() {
            if include[k] && replies[k].is_none() && !self.dead[k] {
                self.dead[k] = true;
                self.lost[k] = true; // the shard died with the process
                self.alive[k] = false;
                self.objective_dirty = true;
                // a dropped partial term changes the accumulated stats
                self.posterior_cache = None;
                if !self.newly_failed.contains(&k) {
                    self.newly_failed.push(k);
                }
                self.metrics.counter("train.dropped_workers").inc();
                obs::trace::event("worker_dropped", self.eval_version, k as u64);
            }
        }
    }

    fn record_round(&mut self, replies: &[Option<WorkerReply>], wall: f64) {
        let mut worker_secs = vec![0.0; self.cfg.workers];
        let (mut tx, mut rx) = (0u64, 0u64);
        let mut psi = 0u64;
        for r in replies.iter().flatten() {
            worker_secs[r.worker] = r.secs;
            tx += r.bytes_tx;
            rx += r.bytes_rx;
            psi += u64::from(r.psi_fills);
        }
        self.rounds.push(RoundTiming {
            worker_secs,
            wall_secs: wall,
            bytes_tx: tx,
            bytes_rx: rx,
            psi_recomputes: psi,
            math_mode: self.cfg.math_mode,
            fill_threads: self.cfg.fill_threads.max(1),
        });
    }

    /// Rounds 1+2 at global parameters `theta`: distributed bound value
    /// and gradient. Applies local worker updates when the one-shot
    /// `update_locals_next` flag is set (paper step 4's "at the same
    /// time the end-point nodes optimise L_k").
    fn eval_globals(&mut self, theta: &[f64]) -> Result<(f64, Vec<f64>)> {
        let params = self.params.unflatten(theta);
        let include = self.alive.clone();
        // a fresh parameter version per evaluation: the two rounds below
        // share it (workers may reuse round-1 psi intermediates), every
        // other evaluation — including each SCG trial point — gets its own
        self.eval_version += 1;
        let version = self.eval_version;
        // the evaluation version IS the trace id for this evaluation:
        // set it as the ambient id so the TCP backend stamps it onto
        // every leader->worker frame, and the workers' spans line up
        // with the two round spans below
        obs::trace::set_current(version);

        // ---- round 1: partial statistics --------------------------------
        let round1_span = obs::trace::span("stats_round", version);
        let t0 = Instant::now();
        let replies = self.backend.map_subset(
            &include,
            &Request::Stats {
                params: params.clone(),
                version,
            },
        );
        let wall = t0.elapsed().as_secs_f64();
        drop(round1_span);
        self.metrics
            .histogram("train.stats_round_ns")
            .record((wall * 1e9) as u64);
        self.absorb_backend_failures(&include, &replies);
        self.record_round(&replies, wall);
        let m = params.m();
        let mut stats = Stats::zeros(m, self.dout);
        for r in replies.iter().flatten() {
            match &r.value {
                Response::Stats(s) => stats.accumulate(s),
                Response::Err(e) => bail!("worker {} (stats round): {e}", r.worker),
                other => bail!("worker {}: unexpected stats reply {other:?}", r.worker),
            }
        }

        // ---- central: bound + adjoints -----------------------------------
        let tc = Instant::now();
        let kmm = kernel::kmm(&params, self.cfg.jitter);
        let (bv, adj) = gp::assemble_bound(&stats, &kmm, params.log_beta, self.dout)?;
        self.central_secs += tc.elapsed().as_secs_f64();

        // ---- round 2: chain-rule gradients (+ local updates) -------------
        let do_locals = self.update_locals_next;
        self.update_locals_next = false;
        let include2 = self.alive.clone();
        let round2_span = obs::trace::span("grads_round", version);
        let t1 = Instant::now();
        let greplies = self.backend.map_subset(
            &include2,
            &Request::Grads {
                params: params.clone(),
                adj: adj.clone(),
                update_locals: do_locals,
                version,
            },
        );
        let wall1 = t1.elapsed().as_secs_f64();
        drop(round2_span);
        self.metrics
            .histogram("train.grads_round_ns")
            .record((wall1 * 1e9) as u64);
        self.absorb_backend_failures(&include2, &greplies);
        self.record_round(&greplies, wall1);

        let tc2 = Instant::now();
        let mut total = GlobalGrads::zeros(m, params.q());
        for r in greplies.iter().flatten() {
            match &r.value {
                Response::Grads(g) => total.accumulate(g),
                Response::Err(e) => bail!("worker {} (gradient round): {e}", r.worker),
                other => bail!("worker {}: unexpected gradient reply {other:?}", r.worker),
            }
        }
        // central direct term (native pullback of dF/dKmm through Kmm(Z))
        total.accumulate(&kernel::kmm_vjp(&params, &adj.d_kmm));
        total.d_log_beta = adj.d_log_beta;
        self.central_secs += tc2.elapsed().as_secs_f64();

        self.last_f = bv.f;
        // minimise -F
        Ok((-bv.f, total.flatten().iter().map(|g| -g).collect()))
    }

    /// One outer iteration of the §3.2 protocol. Returns the bound F at
    /// the iteration's accepted point.
    pub fn step(&mut self) -> Result<f64> {
        let iter = self.log.iterations.len();
        // tagged with the FIRST evaluation version this step will use,
        // so the step span and its inner round spans share a prefix of
        // ids; `n` records how many evaluations the optimiser ran
        let mut step_span = obs::trace::span("global_step", self.eval_version + 1);
        let evals_before = self.eval_version;
        self.rounds.clear();
        self.central_secs = 0.0;
        // invalidate up front, not only at the end: an error mid-step
        // can leave parameters/worker locals already moved, and a
        // caller that catches it must never be served stale weights
        self.posterior_cache = None;
        // NOTE: newly_failed is NOT cleared here — deaths absorbed
        // between steps (evaluate/current_stats/predict) carry into
        // this iteration's failure log instead of vanishing.

        // membership: periodically probe the backend; a lost connection
        // becomes a permanent §5.2 drop before the round even starts.
        // Rate-limited: mid-round deaths are caught by the map rounds
        // themselves (absorb_backend_failures), so the healthy path
        // does not pay a ping round-trip per iteration.
        let now = Instant::now();
        let due = self.last_heartbeat.map_or(true, |t| {
            now.duration_since(t).as_secs_f64() >= self.cfg.heartbeat_secs
        });
        if due {
            self.last_heartbeat = Some(now);
            let hb = self.backend.heartbeat();
            for k in 0..self.cfg.workers {
                if !hb[k] && !self.dead[k] {
                    self.dead[k] = true;
                    self.lost[k] = true; // no chance to fetch the shard back
                    self.objective_dirty = true;
                    self.posterior_cache = None;
                    self.newly_failed.push(k);
                    self.metrics.counter("train.dropped_workers").inc();
                    obs::trace::event("worker_dropped", self.eval_version, k as u64);
                }
            }
            // record each worker's last-heard-from age, not just the
            // boolean liveness the probe returned (satellite: a slow
            // worker shows up as a growing age long before it dies)
            for (k, age) in self.backend.heartbeat_ages().into_iter().enumerate() {
                if let Some(age) = age {
                    self.metrics
                        .gauge(&format!("train.worker.{k}.heartbeat_age_ms"))
                        .set((age * 1e3) as u64);
                }
            }
        }

        // node-failure injection for this iteration (paper Fig. 7);
        // permanently lost nodes stay down
        let mut failed = Vec::new();
        for k in 0..self.cfg.workers {
            if self.dead[k] {
                self.alive[k] = false;
                continue;
            }
            let down = self.cfg.failure_rate > 0.0 && self.rng.flip(self.cfg.failure_rate);
            self.alive[k] = !down;
            if down {
                failed.push(k);
            }
        }
        if !self.alive.iter().any(|a| *a) {
            // never drop the whole cluster; revive the first live node
            match (0..self.cfg.workers).find(|k| !self.dead[*k]) {
                Some(k) => {
                    self.alive[k] = true;
                    failed.retain(|f| *f != k);
                }
                None => bail!("every worker in the cluster is dead"),
            }
        }

        let mut accepted_f = f64::NAN;
        match self.cfg.global_opt {
            GlobalOpt::Scg => {
                // take SCG out of self to avoid a double borrow in the
                // objective closure
                let mut scg = self.scg.take();
                let theta0 = self.params.flatten();
                // the first eval of the iteration happens at the current
                // accepted point and carries the workers' local updates
                // ("at the same time the end-point nodes optimise L_k");
                // SCG's probe/candidate evals do not.
                let lvm = self.cfg.model == ModelKind::Lvm;
                self.update_locals_next = lvm;
                // re-anchoring is only needed when the objective moved under
                // SCG's feet: local updates (LVM) or dropped nodes. Pure
                // regression with no failures skips the refresh eval —
                // a 1/3 round saving per iteration (EXPERIMENTS.md §Perf).
                let dirty = self.objective_dirty || lvm || !failed.is_empty();
                self.objective_dirty = !failed.is_empty();
                let result = (|| -> Result<()> {
                    let mut err: Option<anyhow::Error> = None;
                    {
                        let mut obj = |x: &[f64]| match self.eval_globals(x) {
                            Ok(v) => v,
                            Err(e) => {
                                err = Some(e);
                                (f64::INFINITY, vec![0.0; x.len()])
                            }
                        };
                        match scg.as_mut() {
                            None => {
                                scg = Some(Scg::new(theta0, &mut obj));
                            }
                            Some(s) => {
                                if dirty {
                                    s.refresh(&mut obj);
                                }
                            }
                        }
                        scg.as_mut().unwrap().step(&mut obj);
                    }
                    if let Some(e) = err {
                        return Err(e);
                    }
                    Ok(())
                })();
                let scg = scg.expect("scg initialised above");
                self.params = self.params.unflatten(scg.x());
                // report the bound at the ACCEPTED point (scg minimises -F),
                // not at whatever probe/candidate ran last
                accepted_f = -scg.f();
                self.scg = Some(scg);
                result?;
            }
            GlobalOpt::Adam { lr } => {
                let mut theta = self.params.flatten();
                self.update_locals_next = self.cfg.model == ModelKind::Lvm;
                let (_, grad) = self.eval_globals(&theta)?;
                if self.adam.is_none() {
                    self.adam = Some(Adam::new(theta.len(), lr));
                }
                self.adam.as_mut().unwrap().step(&mut theta, &grad);
                self.params = self.params.unflatten(&theta);
                accepted_f = self.last_f;
            }
        }

        // the iteration's failure record: transient injections plus
        // connections lost mid-iteration or since the last step
        for k in std::mem::take(&mut self.newly_failed) {
            if !failed.contains(&k) {
                failed.push(k);
            }
        }
        failed.sort_unstable();

        // the accepted step moved the global parameters (and, for the
        // LVM, the workers' locals): any cached posterior is stale
        self.posterior_cache = None;

        let f = accepted_f;
        self.log.iterations.push(IterationLog {
            iter,
            f,
            rounds: std::mem::take(&mut self.rounds),
            central_secs: self.central_secs,
            failed_workers: failed,
        });
        step_span.set_count(self.eval_version - evals_before);
        Ok(f)
    }

    /// Run `iters` outer iterations; returns the final bound.
    pub fn train(&mut self, iters: usize) -> Result<f64> {
        let mut f = f64::NAN;
        for _ in 0..iters {
            f = self.step()?;
        }
        Ok(f)
    }

    /// Evaluate the bound at the current parameters without stepping
    /// (all live nodes, no failure injection).
    pub fn evaluate(&mut self) -> Result<f64> {
        let saved = self.alive.clone();
        self.alive = (0..self.cfg.workers).map(|k| !self.dead[k]).collect();
        let theta = self.params.flatten();
        let (neg_f, _) = self.eval_globals(&theta)?;
        self.alive = saved;
        Ok(-neg_f)
    }

    /// Accumulated statistics at the current parameters (for posterior
    /// weights / prediction).
    pub fn current_stats(&mut self) -> Result<Stats> {
        let include: Vec<bool> = (0..self.cfg.workers).map(|k| !self.dead[k]).collect();
        // a standalone statistics round is its own evaluation: give it a
        // fresh version so no later gradient round can alias its scratch
        self.eval_version += 1;
        let replies = self.backend.map_subset(
            &include,
            &Request::Stats {
                params: self.params.clone(),
                version: self.eval_version,
            },
        );
        self.absorb_backend_failures(&include, &replies);
        let mut stats = Stats::zeros(self.params.m(), self.dout);
        for r in replies.iter().flatten() {
            match &r.value {
                Response::Stats(s) => stats.accumulate(s),
                Response::Err(e) => bail!("worker {}: {e}", r.worker),
                other => bail!("worker {}: unexpected reply {other:?}", r.worker),
            }
        }
        Ok(stats)
    }

    /// Posterior weights at the current parameters.
    ///
    /// The first call after a parameter change runs one cluster
    /// statistics round; the result is cached so every further
    /// `posterior`/`predict`/`export_model` at the same parameters is
    /// served centrally with zero map rounds and bit-identical
    /// weights. Steps, local q(X) updates, re-sharding and node deaths
    /// all invalidate the cache (event-driven, not version-compared).
    pub fn posterior(&mut self) -> Result<gp::PosteriorWeights> {
        if let Some(w) = &self.posterior_cache {
            self.posterior_hits += 1;
            return Ok(w.clone());
        }
        let stats = self.current_stats()?;
        let kmm = kernel::kmm(&self.params, self.cfg.jitter);
        let w = gp::bound::posterior_weights(&stats, &kmm, self.params.log_beta)?;
        self.posterior_cache = Some(w.clone());
        Ok(w)
    }

    /// Posterior requests served from the cache since construction.
    pub fn posterior_cache_hits(&self) -> u64 {
        self.posterior_hits
    }

    /// Export the product of training as a self-contained, serializable
    /// [`crate::model::TrainedModel`]: the global parameters, the
    /// posterior weights over the m inducing points (computed from the
    /// final statistics round — cached by `eval_version`, so exporting
    /// after a `predict` costs no extra cluster round) and provenance.
    /// Works over any backend; the artifact it returns needs none.
    pub fn export_model(&mut self) -> Result<crate::model::TrainedModel> {
        let weights = self.posterior()?;
        let model = crate::model::TrainedModel {
            params: self.params.clone(),
            weights,
            dout: self.dout,
            jitter: self.cfg.jitter,
            math_mode: self.cfg.math_mode,
            meta: crate::model::ModelMeta {
                artifact: self.cfg.artifact.clone(),
                iterations: self.completed_iterations(),
                final_bound: self.completed_bound(),
                seed: self.cfg.seed,
            },
        };
        model.validate()?;
        Ok(model)
    }

    /// Snapshot the global parameters mid-training (the optimiser
    /// re-anchors on resume; worker-local q(X) state lives with the
    /// shards and is not part of the global checkpoint).
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let ckpt = crate::model::Checkpoint {
            params: self.params.clone(),
            iterations: self.completed_iterations(),
            last_bound: self.completed_bound(),
            artifact: self.cfg.artifact.clone(),
            math_mode: self.cfg.math_mode,
            seed: self.cfg.seed,
        };
        ckpt.save(path)
    }

    /// Resume from a checkpoint: validate it against this trainer's
    /// artifact and shapes, install its global parameters and reset the
    /// optimiser state so SCG re-anchors at the restored point. Returns
    /// the checkpoint's completed-iteration count.
    pub fn restore_checkpoint(&mut self, path: &std::path::Path) -> Result<u64> {
        let ckpt = crate::model::Checkpoint::load(path)?;
        ensure!(
            ckpt.artifact == self.cfg.artifact,
            "checkpoint was trained under artifact {:?} but this trainer runs {:?}",
            ckpt.artifact,
            self.cfg.artifact
        );
        ensure!(
            ckpt.params.m() == self.params.m() && ckpt.params.q() == self.params.q(),
            "checkpoint shapes (m={}, q={}) do not match this trainer (m={}, q={})",
            ckpt.params.m(),
            ckpt.params.q(),
            self.params.m(),
            self.params.q()
        );
        self.params = ckpt.params.clone();
        self.scg = None;
        self.adam = None;
        self.objective_dirty = true;
        self.posterior_cache = None;
        self.resumed_iterations = ckpt.iterations;
        self.resumed_bound = ckpt.last_bound;
        Ok(ckpt.iterations)
    }

    /// Fetch the live workers' current local parameters (gather; used by
    /// the LVM experiments to inspect the learned embedding), in worker
    /// order. Any unavailable shard is an error — silently omitting one
    /// would leave rows missing from the assembled embedding. Workers
    /// whose process died with their shard (`lost`) therefore fail the
    /// gather.
    ///
    /// Each entry is `(row_ids, xmu, xvar)`: `row_ids[i]` is the
    /// **original dataset row index** of shard row `i`. After a
    /// [`Self::decommission`] the moved rows sit at the survivors'
    /// tails, so the concatenated shard order is a permutation of the
    /// dataset order — the indices let callers scatter rows back to
    /// their original positions (see `experiments::common::gathered_xmu`)
    /// instead of silently mispairing rows with labels.
    pub fn gather_locals(&mut self) -> Result<Vec<(Vec<usize>, Matrix, Matrix)>> {
        if let Some(k) = (0..self.cfg.workers).find(|k| self.lost[*k]) {
            bail!(
                "worker {k}'s shard was lost with its process (§5.2 drop path); \
                 the gathered local parameters would be incomplete"
            );
        }
        let include: Vec<bool> = (0..self.cfg.workers).map(|k| !self.dead[k]).collect();
        let replies = self.backend.map_subset(&include, &Request::GatherLocals);
        let mut locals = Vec::new();
        for (k, slot) in replies.into_iter().enumerate() {
            let Some(r) = slot else {
                if include[k] {
                    bail!("worker {k} unreachable during gather");
                }
                continue;
            };
            match r.value {
                Response::Locals { xmu, xvar } => locals.push((k, xmu, xvar)),
                Response::Err(e) => bail!("worker {k} (gather): {e}"),
                other => bail!("worker {k}: unexpected gather reply {other:?}"),
            }
        }
        // `with_backend` bring-up: the layout is still the contiguous
        // dataset order (no decommission can have run without row ids),
        // so reconstruct the index map from the gathered shard sizes
        if self.row_ids.is_none() {
            let mut ids = vec![Vec::new(); self.cfg.workers];
            let mut offset = 0usize;
            for (k, xmu, _) in &locals {
                ids[*k] = (offset..offset + xmu.rows()).collect();
                offset += xmu.rows();
            }
            self.row_ids = Some(ids);
        }
        let row_ids = self.row_ids.as_ref().expect("populated above");
        let mut out = Vec::with_capacity(locals.len());
        for (k, xmu, xvar) in locals {
            ensure!(
                row_ids[k].len() == xmu.rows(),
                "worker {k} gathered {} rows but the leader's row-index map holds {} \
                 (shard mutated outside the trainer?)",
                xmu.rows(),
                row_ids[k].len()
            );
            out.push((row_ids[k].clone(), xmu, xvar));
        }
        Ok(out)
    }

    /// Populate the row-index map for a `with_backend` bring-up by
    /// gathering the current shard sizes (no-op when already known —
    /// i.e. for every sharded constructor). Documented cost: the
    /// gather ships each shard's full (xmu, xvar) back just to learn
    /// its row count; acceptable because only the pre-initialised
    /// `with_backend` escape hatch can reach it, and at most once.
    fn ensure_row_ids(&mut self) -> Result<()> {
        if self.row_ids.is_some() {
            return Ok(());
        }
        self.gather_locals().map(|_| ())
    }

    /// Predict through the first live worker's executor (any node serves).
    pub fn predict(&mut self, xt_mu: &Matrix, xt_var: &Matrix) -> Result<(Matrix, Vec<f64>)> {
        let w = self.posterior()?;
        let k = (0..self.cfg.workers)
            .find(|k| !self.dead[*k])
            .ok_or_else(|| anyhow!("no live workers"))?;
        let reply = self
            .backend
            .map_one(
                k,
                &Request::Predict {
                    params: self.params.clone(),
                    xt_mu: xt_mu.clone(),
                    xt_var: xt_var.clone(),
                    w1: w.w1,
                    wv: w.wv,
                },
            )
            .ok_or_else(|| anyhow!("worker {k} unreachable"))?;
        match reply.value {
            Response::Predict { mean, var } => Ok((mean, var)),
            Response::Err(e) => bail!("worker {k}: {e}"),
            other => bail!("worker {k}: unexpected predict reply {other:?}"),
        }
    }
}

/// Split a slice into `k` contiguous chunks with exactly the sizes
/// [`partition`] produces (`base + 1` for the first `n % k` chunks) —
/// the row-index mirror of the decommission re-shard.
fn split_even(ids: &[usize], k: usize) -> Vec<Vec<usize>> {
    let n = ids.len();
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut lo = 0;
    for i in 0..k {
        let hi = lo + base + usize::from(i < extra);
        out.push(ids[lo..hi].to_vec());
        lo = hi;
    }
    out
}

/// Partition a dataset into `k` contiguous shards of near-equal size
/// (the paper distributes points evenly across nodes).
pub fn partition(
    xmu: &Matrix,
    xvar: &Matrix,
    y: &Matrix,
    kl_weight: f64,
    k: usize,
) -> Vec<ShardData> {
    let n = xmu.rows();
    let mut out = Vec::with_capacity(k);
    let base = n / k;
    let extra = n % k;
    let mut lo = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        let hi = lo + len;
        let take = |src: &Matrix| Matrix::from_fn(hi - lo, src.cols(), |r, c| src[(lo + r, c)]);
        out.push(ShardData {
            xmu: take(xmu),
            xvar: take(xvar),
            y: take(y),
            kl_weight,
        });
        lo = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_all_points_once() {
        let n = 23;
        let xmu = Matrix::from_fn(n, 2, |i, j| (i * 2 + j) as f64);
        let xvar = Matrix::zeros(n, 2);
        let y = Matrix::from_fn(n, 3, |i, _| i as f64);
        let shards = partition(&xmu, &xvar, &y, 0.0, 5);
        assert_eq!(shards.len(), 5);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, n);
        // sizes differ by at most 1
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        // first row of shard 1 follows last row of shard 0
        assert_eq!(shards[1].y[(0, 0)], shards[0].len() as f64);
    }

    /// `split_even` must produce exactly the chunk sizes `partition`
    /// produces — the invariant the decommission row-index mirror
    /// rests on.
    #[test]
    fn split_even_mirrors_partition_chunking() {
        for n in [0usize, 1, 5, 23, 24, 97] {
            for k in [1usize, 2, 3, 5, 7] {
                let ids: Vec<usize> = (100..100 + n).collect();
                let chunks = split_even(&ids, k);
                let xmu = Matrix::zeros(n, 2);
                let shards = partition(&xmu, &xmu, &Matrix::zeros(n, 1), 0.0, k);
                assert_eq!(chunks.len(), shards.len());
                for (c, s) in chunks.iter().zip(&shards) {
                    assert_eq!(c.len(), s.len(), "n={n} k={k}");
                }
                // order-preserving, covering, disjoint
                let flat: Vec<usize> = chunks.into_iter().flatten().collect();
                assert_eq!(flat, ids);
            }
        }
    }
}
