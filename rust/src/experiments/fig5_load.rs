//! Fig. 5: distribution of the load across nodes — min / mean / max
//! worker execution time per iteration, at a small and a large worker
//! count. The paper reports a 3.7% average gap between mean and max,
//! i.e. an even load distribution (requirement 1 of its introduction).

use anyhow::Result;

use crate::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use crate::data::synthetic;
use crate::experiments::common;
use crate::gp::GlobalParams;
use crate::linalg::Matrix;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;

fn run_one(args: &Args, n: usize, workers: usize, iters: usize, seed: u64) -> Result<Trainer> {
    let data = synthetic::generate(n, 0.05, seed);
    let mut rng = Rng::new(seed ^ 9);
    let xmu = Matrix::from_fn(n, 2, |i, j| {
        if j == 0 {
            data.latent[i]
        } else {
            0.1 * rng.normal()
        }
    });
    let shards = partition(&xmu, &Matrix::zeros(n, 2), &data.y, 0.0, workers);
    let mut prng = Rng::new(seed ^ 5);
    let params = GlobalParams {
        z: Matrix::from_fn(64, 2, |_, _| prng.range(-3.0, 3.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let cfg = TrainConfig {
        artifact: "perf".into(),
        artifacts_dir: common::artifacts_dir(args),
        workers,
        model: ModelKind::Regression,
        global_opt: GlobalOpt::Scg,
        seed,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg, params, shards)?;
    t.train(1)?; // warmup
    t.log.iterations.clear();
    t.train(iters)?;
    Ok(t)
}

pub fn run(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 40_000)?;
    let iters = args.get_usize("iters", 5)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let small = args.get_usize("small", 5)?;
    let large = args.get_usize("large", 20)?;

    println!("fig5: per-iteration worker load distribution, n={n}");
    let mut csv = CsvWriter::new(&["workers", "iter", "min_s", "mean_s", "max_s"]);
    for &w in &[small, large] {
        let t = run_one(args, n, w, iters, seed)?;
        println!("  workers = {w}:");
        println!("    {:>5} {:>12} {:>12} {:>12}", "iter", "min", "mean", "max");
        for it in &t.log.iterations {
            let (mn, mean, mx) = it.load_min_mean_max();
            println!("    {:>5} {:>12.5} {:>12.5} {:>12.5}", it.iter, mn, mean, mx);
            csv.row(&[w as f64, it.iter as f64, mn, mean, mx]);
        }
        let gap = t.log.mean_load_gap() * 100.0;
        println!("    mean (max-mean)/mean gap: {gap:.2}%   (paper: 3.7%)");
    }
    let path = common::results_dir(args).join("fig5_load.csv");
    csv.save(&path)?;
    println!("  series -> {}", path.display());
    Ok(())
}
