//! `gparml experiment flights` — the paper-scale flight-delay
//! regression scenario (§4.3's headline regime: 700k training records,
//! 100k held out, 8 covariates). The whole out-of-core pipeline runs
//! end-to-end (DESIGN.md §13): pack a synthetic flight-delay store to
//! disk shard-by-shard, spawn real `gparml worker` processes, stream
//! every worker's partition over TCP chunk-by-chunk (leader peak
//! memory bounded by `--chunk-rows`, never by n), train, and score
//! RMSE on held-out rows. Results land in
//! `BENCH_scenario_flights.json` for the CI scenario gate
//! (`gparml bench check --scenario ...`).
//!
//! `--scale smoke` (default) is the CI mode — ~1.5k rows, seconds,
//! same moving parts. `--scale full` is the paper-scale operator run.

use std::net::TcpListener;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{GlobalOpt, ModelKind, StreamConfig, TrainConfig, Trainer};
use crate::data::flights;
use crate::experiments::{common, scenarios};
use crate::gp::GlobalParams;
use crate::linalg::Matrix;
use crate::store::{ShardedDiskSource, SplitColumns, StoreWriter};
use crate::util::cli::Args;
use crate::util::rng::Rng;

struct Dims {
    n: usize,
    n_test: usize,
    workers: usize,
    iters: usize,
    shard_rows: usize,
    chunk_rows: usize,
}

pub fn run(args: &Args) -> Result<()> {
    let scale = scenarios::scale(args)?;
    let d = if scale == "smoke" {
        Dims {
            n: 1536,
            n_test: 256,
            workers: 2,
            iters: 3,
            shard_rows: 256,
            chunk_rows: 128,
        }
    } else {
        Dims {
            n: 700_000,
            n_test: 100_000,
            workers: 4,
            iters: 40,
            shard_rows: 65_536,
            chunk_rows: 8_192,
        }
    };
    let n = args.get_usize("n", d.n)?;
    let n_test = args.get_usize("n-test", d.n_test)?;
    let workers = args.get_usize("workers", d.workers)?;
    let iters = args.get_usize("iters", d.iters)?;
    let shard_rows = args.get_usize("shard-rows", d.shard_rows)?;
    let chunk_rows = args.get_usize("chunk-rows", d.chunk_rows)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let out = common::results_dir(args);

    println!(
        "flights scenario ({scale}): n={n}, test={n_test}, {workers} worker processes, \
         {iters} iters, shard_rows={shard_rows}, chunk_rows={chunk_rows}"
    );

    // ---- pack: stream the generator into a sharded on-disk store.
    // flights::chunk is chunk-invariant (per-row seeding), so the
    // packer holds at most chunk_rows rows at once.
    let store_dir = out.join(format!("flights_store_{scale}"));
    std::fs::remove_dir_all(&store_dir).ok();
    let t0 = Instant::now();
    let mut w = StoreWriter::create(
        &store_dir,
        flights::INPUT_COLS,
        shard_rows,
        Some("flights"),
    )?;
    let mut row = 0usize;
    while row < n {
        let rows = chunk_rows.min(n - row);
        w.append(&flights::chunk(seed, row, rows))?;
        row += rows;
    }
    let man = w.finish()?;
    let pack_secs = t0.elapsed().as_secs_f64();
    println!(
        "  packed {} rows into {} shard(s) at {} ({pack_secs:.2}s, {:.0} rows/s)",
        man.n,
        man.shards.len(),
        store_dir.display(),
        man.n as f64 / pack_secs.max(1e-9)
    );

    // ---- bring-up: real worker processes over localhost TCP, shards
    // streamed from the store (the leader never materialises the data)
    let src = ShardedDiskSource::open(&store_dir)?;
    let art = common::manifest(args)?.config("flights")?.clone();
    let art_dir = common::artifacts_dir(args);
    let listener = TcpListener::bind("127.0.0.1:0").context("binding the leader listener")?;
    let addr = listener.local_addr()?.to_string();
    let procs = scenarios::spawn_workers(workers, &addr, &art_dir)?;
    let cfg = TrainConfig {
        artifact: "flights".into(),
        artifacts_dir: art_dir,
        workers,
        model: ModelKind::Regression,
        global_opt: GlobalOpt::Scg,
        math_mode: common::math_mode(args)?,
        fill_threads: common::fill_threads(args)?,
        seed,
        ..Default::default()
    };
    let mut rng = Rng::new(seed ^ 1);
    let params = GlobalParams {
        z: Matrix::from_fn(art.m, art.q, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0; art.q],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let mapper = SplitColumns {
        x_cols: flights::INPUT_COLS,
    };
    let stream = StreamConfig {
        source: &src,
        mapper: &mapper,
        chunk_rows,
        kl_weight: 0.0,
        shard_refs: None,
    };
    let mut t = Trainer::accept_tcp_streaming(cfg, params, &stream, &listener)?;
    println!(
        "  cluster up in {:.2}s (streamed bring-up, leader holds <= {chunk_rows} rows)",
        t.log.startup_secs
    );

    // ---- train, reporting the bound trajectory and throughput
    let mut bound = f64::NAN;
    let mut train_secs = 0.0;
    for i in 0..iters {
        let ti = Instant::now();
        bound = t.step()?;
        let secs = ti.elapsed().as_secs_f64();
        train_secs += secs;
        println!(
            "  iter {i:>3}: F = {bound:.4}  ({secs:.2}s, {:.0} rows/s)",
            n as f64 / secs.max(1e-9)
        );
    }

    // ---- held-out RMSE: test rows are just the generator's rows
    // [n, n + n_test), predicted in bounded batches
    let mut sq = 0.0;
    let mut dsum = 0.0;
    let mut dsq = 0.0;
    let mut row = n;
    let end = n + n_test;
    while row < end {
        let rows = 4096.min(end - row);
        let test = flights::chunk(seed, row, rows);
        let xt = Matrix::from_fn(rows, flights::INPUT_COLS, |i, j| test[(i, j)]);
        let (mean, _) = t.predict(&xt, &Matrix::zeros(rows, flights::INPUT_COLS))?;
        for i in 0..rows {
            let delay = test[(i, flights::INPUT_COLS)];
            let r = mean[(i, 0)] - delay;
            sq += r * r;
            dsum += delay;
            dsq += delay * delay;
        }
        row += rows;
    }
    let rmse = (sq / n_test as f64).sqrt();
    let dmean = dsum / n_test as f64;
    let delay_std = (dsq / n_test as f64 - dmean * dmean).max(0.0).sqrt();
    let (tx, rx) = t.log.total_network_bytes();
    println!(
        "  RMSE {rmse:.4} over {n_test} held-out rows (test delay std {delay_std:.4}); \
         network {tx} tx / {rx} rx bytes"
    );

    let report = scenarios::ScenarioReport {
        scenario: "flights",
        scale: scale.into(),
        shape: vec![
            ("n", n),
            ("n_test", n_test),
            ("workers", workers),
            ("iters", iters),
            ("shard_rows", shard_rows),
            ("chunk_rows", chunk_rows),
            ("m", art.m),
        ],
        series: vec![
            ("pack_ns_per_row", scenarios::ns_per_row(pack_secs, n)),
            ("train_ns_per_row", scenarios::ns_per_row(train_secs, n * iters)),
        ],
        info: vec![
            ("train_rows_per_sec", (n * iters) as f64 / train_secs.max(1e-9)),
            ("rmse", rmse),
            ("test_delay_std", delay_std),
            ("final_bound", bound),
        ],
    };
    let path = scenarios::write_report(&out, &report)?;
    println!("  report -> {}", path.display());
    drop(t); // sends Shutdown frames before the kill-on-drop guard fires
    drop(procs);
    Ok(())
}
