//! Fig. 4: latent space of the oil-flow dataset — parallel inference vs
//! the sequential reference implementation (GPy in the paper, our
//! `baselines::sequential` here; identical numerics, different
//! process structure).
//!
//! Reported: final bounds, ARD relevance profiles (paper: all but one
//! ARD parameter decreases toward zero), class separation of the two
//! embeddings, and the full scatter data as CSV.

use anyhow::Result;

use crate::baselines::sequential::SequentialTrainer;
use crate::coordinator::partition;
use crate::data::oilflow;
use crate::experiments::common;
use crate::runtime::ShardData;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

pub fn run(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 600)?;
    let iters = args.get_usize("iters", 40)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let workers = args.get_usize("workers", 5)?;
    let data = oilflow::generate(n, seed);
    let (m, q) = (32, 6); // "oil" artifact shapes

    // --- distributed run --------------------------------------------------
    let (mut dist, init) = common::lvm_trainer(args, "oil", &data.y, m, q, workers, seed)?;
    let f0 = dist.evaluate()?;
    let f_dist = dist.train(iters)?;
    let xmu_dist = common::gathered_xmu(&mut dist, q)?;
    let ard_dist = common::ard_relevance(&dist.params);

    // --- sequential reference (same init) ---------------------------------
    let manifest = common::manifest(args)?;
    let shard = ShardData {
        xmu: init.xmu.clone(),
        xvar: init.xvar.clone(),
        y: data.y.clone(),
        kl_weight: 1.0,
    };
    let mut seq = SequentialTrainer::new(
        &manifest,
        "oil",
        init.params.clone(),
        shard,
        true,
        0.05,
    )?;
    let f_seq = seq.train(iters)?;
    let (xmu_seq, _) = seq.locals();
    let ard_seq = common::ard_relevance(&seq.params);

    // --- comparison --------------------------------------------------------
    let sep_dist = common::class_separation(&xmu_dist, &data.labels);
    let sep_seq = common::class_separation(xmu_seq, &data.labels);
    // verify both runs share the partition invariance: the same shards fed
    // through the two paths start from the same bound
    println!("fig4: oil-flow-like dataset, n={n}, q={q}, m={m}, {iters} iterations");
    println!("  initial bound (shared init): {f0:.2}");
    println!("  parallel   final bound: {f_dist:.2}  class separation: {sep_dist:.3}");
    println!("  sequential final bound: {f_seq:.2}  class separation: {sep_seq:.3}");
    println!("  parallel   ARD relevances: {ard_dist:.3?}");
    println!("  sequential ARD relevances: {ard_seq:.3?}");
    let active = |ard: &[f64]| ard.iter().filter(|v| **v > 0.2).count();
    println!(
        "  active latent dims (relevance > 0.2): parallel {}, sequential {}  (paper: embeddings qualitatively similar; ~1 dominant dim on oilflow)",
        active(&ard_dist),
        active(&ard_seq)
    );

    let mut csv = CsvWriter::new(&["label", "dist_x1", "dist_x2", "seq_x1", "seq_x2"]);
    // plot coordinates: the two most relevant dims of each embedding
    let top2 = |ard: &[f64]| {
        let mut idx: Vec<usize> = (0..ard.len()).collect();
        idx.sort_by(|a, b| ard[*b].partial_cmp(&ard[*a]).unwrap());
        (idx[0], idx[1])
    };
    let (d1, d2) = top2(&ard_dist);
    let (s1, s2) = top2(&ard_seq);
    for i in 0..n {
        csv.row(&[
            data.labels[i] as f64,
            xmu_dist[(i, d1)],
            xmu_dist[(i, d2)],
            xmu_seq[(i, s1)],
            xmu_seq[(i, s2)],
        ]);
    }
    let path = common::results_dir(args).join("fig4_oilflow_latents.csv");
    csv.save(&path)?;
    println!("  scatter -> {}", path.display());

    // sanity for the harness itself
    let _ = partition(&init.xmu, &init.xvar, &data.y, 1.0, workers);
    Ok(())
}
