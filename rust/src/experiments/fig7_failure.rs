//! Fig. 7: robustness to node failure — log marginal likelihood (bound)
//! per iteration with per-iteration node failure frequencies of 0%, 1%
//! and 2% on 10 nodes, averaged over repeats.
//!
//! The failure strategy is the paper's §5.2 choice: drop the failed
//! node's partial terms for that iteration and optimise with the noisy
//! gradient (SCG's finite-difference curvature makes it sensitive to
//! this noise — the paper observes convergence to worse optima with
//! higher failure rates; ARD parameters stay qualitatively correct).

use anyhow::Result;

use crate::data::oilflow;
use crate::experiments::common;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::stats;

pub fn run(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 500)?;
    let iters = args.get_usize("iters", 120)?;
    let repeats = args.get_usize("repeats", 2)?;
    let workers = args.get_usize("workers", 10)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let rates = [0.0, 0.01, 0.02];

    let data = oilflow::generate(n, seed);
    println!(
        "fig7: node failure test, {workers} nodes, {iters} iterations, {repeats} repeats"
    );

    let mut curves: Vec<Vec<f64>> = Vec::new();
    let mut finals = Vec::new();
    let mut ards = Vec::new();
    for &rate in &rates {
        let mut avg = vec![0.0; iters];
        let mut ard_last = Vec::new();
        for rep in 0..repeats {
            let (mut t, _) =
                common::lvm_trainer(args, "oil", &data.y, 32, 6, workers, seed + rep as u64)?;
            t.set_failure_rate(rate);
            for i in 0..iters {
                let f = t.step()?;
                avg[i] += f / repeats as f64;
            }
            if rep == repeats - 1 {
                ard_last = common::ard_relevance(&t.params);
            }
        }
        let f_final = *avg.last().unwrap();
        println!(
            "  rate {:>4.1}%: final avg bound {:>12.2}, ARD {:?}",
            rate * 100.0,
            f_final,
            ard_last
                .iter()
                .map(|v| (v * 1000.0).round() / 1000.0)
                .collect::<Vec<_>>()
        );
        finals.push(f_final);
        ards.push(ard_last);
        curves.push(avg);
    }

    println!(
        "  paper shape: 0% converges best; 1% / 2% converge to worse optima \
         (paper: -1500 vs -5000 on oilflow); ordering reproduced: {}",
        if finals[0] >= finals[1] && finals[1] >= finals[2] - 1e-9 {
            "yes"
        } else {
            "partially (stochastic)"
        }
    );
    // paper also reports the failure runs keep one dominant latent dim
    for (rate, ard) in rates.iter().zip(&ards) {
        let dominant = ard.iter().filter(|v| **v > 0.5).count();
        println!(
            "  rate {:>4.1}%: {} dominant latent dim(s)",
            rate * 100.0,
            dominant
        );
    }

    let mut csv = CsvWriter::new(&["iter", "rate0", "rate1", "rate2"]);
    for i in 0..iters {
        csv.row(&[i as f64, curves[0][i], curves[1][i], curves[2][i]]);
    }
    let path = common::results_dir(args).join("fig7_failure.csv");
    csv.save(&path)?;
    println!("  curves -> {}", path.display());
    let _ = stats::mean(&finals);
    Ok(())
}
