//! Fig. 2: running time per iteration vs number of cores for the 100K
//! synthetic dataset — total time, and time spent only in the two
//! Map-Reduce functions.
//!
//! Hardware substitution (DESIGN.md §5): this container exposes ONE
//! physical core, so `workers` are time-sliced threads. Per-worker
//! compute is measured with per-thread CPU clocks and the parallel wall
//! time is *modeled* as `sum over rounds of max_k t_k` — the same
//! accounting the paper uses for its "computations alone" series. The
//! shape claims being reproduced: t ~ c/cores, near-2x speedup on core
//! doubling for the map series, diminishing returns once per-node shards
//! get small, and a visible constant overhead gap for the total series.

use anyhow::Result;

use crate::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use crate::data::synthetic;
use crate::experiments::common;
use crate::gp::GlobalParams;
use crate::linalg::Matrix;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;

pub struct ScalePoint {
    pub workers: usize,
    pub modeled_parallel: f64,
    pub total_compute: f64,
    pub measured_wall: f64,
    pub overhead: f64,
}

/// Measure mean per-iteration times for one worker count.
pub fn measure(
    args: &Args,
    n: usize,
    workers: usize,
    iters: usize,
    seed: u64,
) -> Result<(ScalePoint, f64)> {
    let data = synthetic::generate(n, 0.05, seed);
    let mut rng = Rng::new(seed ^ 77);
    // regression on the true latent (keeps the workload identical across
    // worker counts; LVM local updates don't change the map cost shape)
    let xmu = Matrix::from_fn(n, 2, |i, j| {
        if j == 0 {
            data.latent[i]
        } else {
            0.1 * rng.normal()
        }
    });
    let xvar = Matrix::zeros(n, 2);
    let shards = partition(&xmu, &xvar, &data.y, 0.0, workers);
    let mut prng = Rng::new(seed ^ 3);
    let params = GlobalParams {
        z: Matrix::from_fn(64, 2, |_, _| prng.range(-3.0, 3.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let cfg = TrainConfig {
        artifact: "perf".into(),
        artifacts_dir: common::artifacts_dir(args),
        workers,
        model: ModelKind::Regression,
        global_opt: GlobalOpt::Scg,
        seed,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg, params, shards)?;
    t.train(1)?; // warmup (first-touch costs)
    t.log.iterations.clear();
    t.train(iters)?;
    let modeled = t.log.mean_iteration_modeled_secs();
    let compute = t.log.mean_iteration_compute_secs();
    let wall: f64 = t
        .log
        .iterations
        .iter()
        .map(|i| i.measured_wall_secs())
        .sum::<f64>()
        / iters as f64;
    Ok((
        ScalePoint {
            workers,
            modeled_parallel: modeled,
            total_compute: compute,
            measured_wall: wall,
            overhead: (wall - compute).max(0.0),
        },
        t.log.startup_secs,
    ))
}

pub fn run(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 100_000)?;
    let iters = args.get_usize("iters", 2)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let max_workers = args.get_usize("max-workers", 60)?;
    let sweep: Vec<usize> = [1usize, 2, 5, 10, 20, 30, 60]
        .into_iter()
        .filter(|w| *w <= max_workers)
        .collect();

    println!("fig2: time per iteration vs cores, n={n} synthetic points");
    println!(
        "{:>8} {:>16} {:>16} {:>16} {:>12}",
        "workers", "modeled par (s)", "map compute (s)", "measured wall", "overhead"
    );
    let mut csv = CsvWriter::new(&[
        "workers",
        "modeled_parallel_s",
        "map_compute_s",
        "measured_wall_s",
        "overhead_s",
    ]);
    let mut points = Vec::new();
    for &w in &sweep {
        let (p, _startup) = measure(args, n, w, iters, seed)?;
        println!(
            "{:>8} {:>16.4} {:>16.4} {:>16.4} {:>12.4}",
            p.workers, p.modeled_parallel, p.total_compute, p.measured_wall, p.overhead
        );
        csv.row(&[
            p.workers as f64,
            p.modeled_parallel,
            p.total_compute,
            p.measured_wall,
            p.overhead,
        ]);
        points.push(p);
    }

    // the paper's headline ratios
    let find = |w: usize| points.iter().find(|p| p.workers == w);
    if let (Some(a), Some(b)) = (find(5), find(10)) {
        println!(
            "  5 -> 10 cores speedup (modeled, map-only): {:.3}x   (paper: 1.99x)",
            a.modeled_parallel / b.modeled_parallel
        );
    }
    if let (Some(a), Some(b)) = (find(30), find(60)) {
        println!(
            "  30 -> 60 cores speedup (modeled, map-only): {:.3}x  (paper: 1.644x)",
            a.modeled_parallel / b.modeled_parallel
        );
    }
    let path = common::results_dir(args).join("fig2_core_scaling.csv");
    csv.save(&path)?;
    println!("  series -> {}", path.display());
    Ok(())
}
