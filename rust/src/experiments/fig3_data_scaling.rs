//! Fig. 3: time per iteration when scaling the computational resources
//! proportionally to the dataset size, with the sequential ("GPy")
//! implementation for comparison.
//!
//! Ideal: constant time as (n, workers) double together. Paper's
//! measured shape: +67% total / +35% map-only over a 60x data scale;
//! the sequential implementation grows linearly and becomes untenable.

use anyhow::Result;

use crate::baselines::sequential::SequentialTrainer;
use crate::data::synthetic;
use crate::experiments::common::{self};
use crate::experiments::fig2_core_scaling::measure;
use crate::gp::GlobalParams;
use crate::linalg::Matrix;
use crate::runtime::ShardData;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;
use crate::util::stats;

pub fn run(args: &Args) -> Result<()> {
    let base_n = args.get_usize("base-n", 2000)?;
    let iters = args.get_usize("iters", 2)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let max_workers = args.get_usize("max-workers", 50)?;
    // (workers, n) pairs: n scales with workers (paper: 60x range)
    let sweep: Vec<usize> = [1usize, 2, 5, 10, 20, 50]
        .into_iter()
        .filter(|w| *w <= max_workers)
        .collect();

    println!("fig3: data scaled with workers, base n/worker = {base_n}");
    println!(
        "{:>8} {:>9} {:>16} {:>16} {:>16} {:>16}",
        "workers", "n", "modeled par (s)", "map compute (s)", "wall (s)", "sequential (s)"
    );
    let mut csv = CsvWriter::new(&[
        "workers",
        "n",
        "modeled_parallel_s",
        "map_compute_s",
        "measured_wall_s",
        "sequential_s",
    ]);
    let mut first_modeled = None;
    let mut last_modeled = None;
    let mut first_compute = None;
    let mut last_compute = None;
    for &w in &sweep {
        let n = base_n * w;
        let (p, _) = measure(args, n, w, iters, seed)?;
        // sequential reference on the same data size (single shard,
        // single thread, identical numerics) — the "GPy" line
        let seq_secs = sequential_iteration_secs(args, n, iters.min(2), seed)?;
        println!(
            "{:>8} {:>9} {:>16.4} {:>16.4} {:>16.4} {:>16.4}",
            w, n, p.modeled_parallel, p.total_compute, p.measured_wall, seq_secs
        );
        csv.row(&[
            w as f64,
            n as f64,
            p.modeled_parallel,
            p.total_compute,
            p.measured_wall,
            seq_secs,
        ]);
        if first_modeled.is_none() {
            first_modeled = Some(p.modeled_parallel);
            first_compute = Some(p.total_compute / w as f64);
        }
        last_modeled = Some(p.modeled_parallel);
        last_compute = Some(p.total_compute / w as f64);
    }
    if let (Some(f), Some(l)) = (first_modeled, last_modeled) {
        println!(
            "  modeled per-iteration growth over {}x data: {:+.1}%   (paper total: +67%)",
            sweep.last().unwrap(),
            (l / f - 1.0) * 100.0
        );
    }
    if let (Some(f), Some(l)) = (first_compute, last_compute) {
        println!(
            "  per-worker map compute growth: {:+.1}%               (paper map-only: +35%)",
            (l / f - 1.0) * 100.0
        );
    }
    let path = common::results_dir(args).join("fig3_data_scaling.csv");
    csv.save(&path)?;
    println!("  series -> {}", path.display());
    Ok(())
}

/// Mean per-iteration seconds of the sequential trainer at size n.
fn sequential_iteration_secs(args: &Args, n: usize, iters: usize, seed: u64) -> Result<f64> {
    let data = synthetic::generate(n, 0.05, seed);
    let mut rng = Rng::new(seed ^ 77);
    let xmu = Matrix::from_fn(n, 2, |i, j| {
        if j == 0 {
            data.latent[i]
        } else {
            0.1 * rng.normal()
        }
    });
    let shard = ShardData {
        xvar: Matrix::zeros(n, 2),
        xmu,
        y: data.y,
        kl_weight: 0.0,
    };
    let mut prng = Rng::new(seed ^ 3);
    let params = GlobalParams {
        z: Matrix::from_fn(64, 2, |_, _| prng.range(-3.0, 3.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let manifest = common::manifest(args)?;
    let mut t = SequentialTrainer::new(&manifest, "perf", params, shard, false, 0.0)?;
    t.step()?; // warmup
    t.iter_secs.clear();
    t.train(iters)?;
    Ok(stats::mean(&t.iter_secs))
}
