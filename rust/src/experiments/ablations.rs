//! Ablations over the design choices DESIGN.md calls out:
//!
//!  A1  global optimiser: SCG (paper) vs Adam            — quality
//!  A2  refresh-skip on clean regression objectives      — cost per iter
//!  A3  failure recovery: drop-partial-term (paper §5.2's choice)
//!      vs decommission + re-shard (the paper's named alternative)
//!  A4  Kmm jitter sensitivity of the bound
//!
//! `gparml experiment ablations [--iters N]`

use anyhow::Result;

use crate::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use crate::data::synthetic;
use crate::experiments::common;
use crate::gp::{kernel, GlobalParams};
use crate::linalg::Matrix;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;

fn setup(n: usize, seed: u64) -> (Matrix, Matrix, Matrix, GlobalParams) {
    let data = synthetic::generate(n, 0.05, seed);
    let mut rng = Rng::new(seed ^ 31);
    let xmu = Matrix::from_fn(n, 2, |i, j| {
        if j == 0 {
            data.latent[i]
        } else {
            0.1 * rng.normal()
        }
    });
    let params = GlobalParams {
        z: Matrix::from_fn(16, 2, |_, _| rng.range(-3.0, 3.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    (xmu, Matrix::zeros(n, 2), data.y, params)
}

fn trainer(
    args: &Args,
    xmu: &Matrix,
    xvar: &Matrix,
    y: &Matrix,
    params: &GlobalParams,
    workers: usize,
    opt: GlobalOpt,
    failure_rate: f64,
) -> Result<Trainer> {
    let shards = partition(xmu, xvar, y, 0.0, workers);
    let cfg = TrainConfig {
        artifact: "small".into(),
        artifacts_dir: common::artifacts_dir(args),
        workers,
        model: ModelKind::Regression,
        global_opt: opt,
        failure_rate,
        seed: 7,
        ..Default::default()
    };
    Trainer::new(cfg, params.clone(), shards)
}

pub fn run(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 1500)?;
    let iters = args.get_usize("iters", 25)?;
    let (xmu, xvar, y, params) = setup(n, 0);
    let mut csv = CsvWriter::new(&["ablation", "variant", "final_bound", "mean_iter_compute_s"]);

    // ---- A1: SCG vs Adam -------------------------------------------------
    println!("A1: global optimiser (regression, n={n}, {iters} iters)");
    for (name, opt) in [
        ("scg", GlobalOpt::Scg),
        ("adam_0.05", GlobalOpt::Adam { lr: 0.05 }),
        ("adam_0.01", GlobalOpt::Adam { lr: 0.01 }),
    ] {
        let mut t = trainer(args, &xmu, &xvar, &y, &params, 4, opt, 0.0)?;
        let f = t.train(iters)?;
        let c = t.log.mean_iteration_compute_secs();
        println!("  {name:>10}: final F = {f:>12.2}, compute/iter {c:.3}s");
        csv.row_str(&["A1".into(), name.into(), format!("{f}"), format!("{c}")]);
    }

    // ---- A2: refresh-skip ------------------------------------------------
    // the optimisation is built in for clean regression; quantify it by
    // comparing rounds per iteration against the LVM path (which must
    // re-anchor every iteration).
    println!("\nA2: evaluation rounds per iteration (refresh-skip)");
    {
        let mut t = trainer(args, &xmu, &xvar, &y, &params, 4, GlobalOpt::Scg, 0.0)?;
        t.train(iters.min(10))?;
        let rounds: Vec<usize> = t.log.iterations.iter().map(|i| i.rounds.len()).collect();
        let first = rounds.first().copied().unwrap_or(0);
        let steady = rounds.iter().skip(1).sum::<usize>() as f64 / (rounds.len() - 1).max(1) as f64;
        println!("  regression: first iter {first} rounds, steady-state {steady:.1} rounds/iter");
        println!("  (without the skip every iteration would pay {} rounds)", first);
        csv.row_str(&[
            "A2".into(),
            "steady_rounds".into(),
            format!("{steady}"),
            "0".into(),
        ]);
    }

    // ---- A3: failure recovery strategies ----------------------------------
    println!("\nA3: recovery under failure (4 workers, one node lost at iter 5)");
    {
        // drop-partial-term: transient failures at 10%/iter
        let mut t1 = trainer(args, &xmu, &xvar, &y, &params, 4, GlobalOpt::Scg, 0.10)?;
        let f1 = t1.train(iters)?;
        println!("  drop-partial-term @10%/iter: final F = {f1:.2}");
        csv.row_str(&["A3".into(), "drop_term".into(), format!("{f1}"), "0".into()]);

        // decommission + re-shard: node 2 dies permanently at iteration 5
        let mut t2 = trainer(args, &xmu, &xvar, &y, &params, 4, GlobalOpt::Scg, 0.0)?;
        t2.train(5)?;
        t2.decommission(2)?;
        let f2 = t2.train(iters - 5)?;
        println!("  decommission+reshard (1 of 4 lost): final F = {f2:.2}");
        csv.row_str(&["A3".into(), "reshard".into(), format!("{f2}"), "0".into()]);

        // clean baseline
        let mut t0 = trainer(args, &xmu, &xvar, &y, &params, 4, GlobalOpt::Scg, 0.0)?;
        let f0 = t0.train(iters)?;
        println!("  no failures:                 final F = {f0:.2}");
        println!("  (re-sharding preserves EXACTNESS — the bound uses all n points");
        println!("   again after recovery; drop-term trades exactness for latency)");
        csv.row_str(&["A3".into(), "clean".into(), format!("{f0}"), "0".into()]);
    }

    // ---- A4: jitter sensitivity -------------------------------------------
    println!("\nA4: Kmm jitter sensitivity (bound at fixed params)");
    {
        let shard_stats = kernel::shard_stats(&params, &xmu, &xvar, &y, &vec![1.0; n], 0.0);
        for jitter in [1e-10, 1e-8, 1e-6, 1e-4] {
            let kmm = kernel::kmm(&params, jitter);
            let (bv, _) =
                crate::gp::assemble_bound(&shard_stats, &kmm, params.log_beta, 3)?;
            println!("  jitter {jitter:>8.0e}: F = {:.6}", bv.f);
            csv.row_str(&[
                "A4".into(),
                format!("jitter_{jitter:.0e}"),
                format!("{}", bv.f),
                "0".into(),
            ]);
        }
    }

    let path = common::results_dir(args).join("ablations.csv");
    csv.save(&path)?;
    println!("\n  series -> {}", path.display());
    Ok(())
}
