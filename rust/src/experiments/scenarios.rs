//! Shared plumbing for the paper-scale experiment scenarios
//! (`gparml experiment flights` / `mnist-lvm`, DESIGN.md §13): the
//! smoke/full scale switch, worker-process management for the real
//! multi-process TCP cluster each scenario drives, and the
//! `BENCH_scenario_*.json` report writer whose output the CI gate
//! consumes (`gparml bench check --scenario ...`).

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

use anyhow::{Context, Result};

use crate::util::cli::Args;

/// `--scale smoke|full` (default `smoke`): `smoke` is the CI mode —
/// seconds of wall clock, every moving part of the out-of-core
/// pipeline exercised end-to-end; `full` is the paper-scale operator
/// run (the 700k-row regime of §4.3).
pub fn scale(args: &Args) -> Result<&str> {
    let s = args.get_str("scale", "smoke");
    anyhow::ensure!(
        matches!(s, "smoke" | "full"),
        "--scale expects smoke|full, got {s:?}"
    );
    Ok(s)
}

/// Spawned `gparml worker` processes, killed on drop so an erroring
/// scenario never leaks children.
pub struct WorkerProcs(Vec<Child>);

impl Drop for WorkerProcs {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Spawn `n` worker processes of THIS binary dialing `leader_addr`
/// (`std::env::current_exe()`), so the scenario trains over real
/// processes and real TCP exactly like an operator deployment. Worker
/// stderr is inherited — a worker-side bring-up failure shows up in
/// the scenario's output, not a black hole.
pub fn spawn_workers(n: usize, leader_addr: &str, artifacts: &Path) -> Result<WorkerProcs> {
    let bin = std::env::current_exe().context("resolving the gparml binary path")?;
    let art = artifacts
        .to_str()
        .context("artifacts dir path is not valid UTF-8")?;
    let mut procs = Vec::with_capacity(n);
    for k in 0..n {
        procs.push(
            Command::new(&bin)
                .args(["worker", "--connect", leader_addr, "--artifacts", art])
                .stdout(Stdio::null())
                .stderr(Stdio::inherit())
                .spawn()
                .with_context(|| format!("spawning scenario worker {k}"))?,
        );
    }
    Ok(WorkerProcs(procs))
}

/// One scenario's measured report. `series` keys must end in
/// `_ns_per_row` — they are the gated perf numbers (the scenario gate
/// compares them against `<scenario>_<series>` ceilings in
/// `BENCH_scenario_baseline.json`); `info` carries ungated context
/// (rows/sec, RMSE, bounds, separation scores).
pub struct ScenarioReport {
    /// Baseline key prefix and report file stem (`BENCH_scenario_<x>.json`).
    pub scenario: &'static str,
    pub scale: String,
    /// Integer shape fields (n, workers, iters, ...), in output order.
    pub shape: Vec<(&'static str, usize)>,
    /// Gated `*_ns_per_row` series.
    pub series: Vec<(&'static str, f64)>,
    /// Ungated metrics.
    pub info: Vec<(&'static str, f64)>,
}

/// Write `BENCH_scenario_<scenario>.json` under `dir`; returns the path.
pub fn write_report(dir: &Path, r: &ScenarioReport) -> Result<PathBuf> {
    for (key, _) in &r.series {
        anyhow::ensure!(
            key.ends_with("_ns_per_row"),
            "gated scenario series {key:?} must end in _ns_per_row"
        );
    }
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("BENCH_scenario_{}.json", r.scenario));
    let mut json = String::from("{\n");
    json.push_str(&format!(
        "  \"scenario\": \"{}\",\n  \"scale\": \"{}\"",
        r.scenario, r.scale
    ));
    for (key, v) in &r.shape {
        json.push_str(&format!(",\n  \"{key}\": {v}"));
    }
    for (key, v) in r.series.iter().chain(&r.info) {
        json.push_str(&format!(",\n  \"{key}\": {v:.3}"));
    }
    json.push_str("\n}\n");
    std::fs::write(&path, json).with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// Nanoseconds per row processed — the machine-comparable unit every
/// gated scenario series uses (`secs` wall over `rows` total rows).
pub fn ns_per_row(secs: f64, rows: usize) -> f64 {
    secs * 1e9 / (rows.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn report_writer_emits_gate_compatible_json() {
        let dir = std::env::temp_dir().join(format!("gpds_scen_{}", std::process::id()));
        let r = ScenarioReport {
            scenario: "flights",
            scale: "smoke".into(),
            shape: vec![("n", 1536), ("workers", 2)],
            series: vec![("train_ns_per_row", 123.456), ("pack_ns_per_row", 7.0)],
            info: vec![("rmse", 0.25)],
        };
        let path = write_report(&dir, &r).unwrap();
        assert!(path.ends_with("BENCH_scenario_flights.json"));
        let json = Json::from_file(&path).unwrap();
        assert_eq!(json.get("scenario").unwrap().as_str().unwrap(), "flights");
        assert_eq!(json.get("n").unwrap().as_f64().unwrap(), 1536.0);
        let t = json.get("train_ns_per_row").unwrap().as_f64().unwrap();
        assert!((t - 123.456).abs() < 1e-9);
        assert!(json.get("rmse").unwrap().as_f64().unwrap() > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn report_writer_rejects_ununitted_series() {
        let dir = std::env::temp_dir().join(format!("gpds_scen_bad_{}", std::process::id()));
        let r = ScenarioReport {
            scenario: "flights",
            scale: "smoke".into(),
            shape: vec![],
            series: vec![("train_secs", 1.0)],
            info: vec![],
        };
        let msg = format!("{:#}", write_report(&dir, &r).unwrap_err());
        assert!(msg.contains("_ns_per_row"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ns_per_row_handles_zero_rows() {
        assert!(ns_per_row(1.0, 0).is_finite());
        assert!((ns_per_row(2.0, 1000) - 2e6).abs() < 1e-6);
    }
}
