//! `gparml experiment mnist-lvm` — the paper-scale GPLVM scenario
//! (§4.5's regime: a density model over tens of thousands of digit
//! images). The dataset is packed as an outputs-only store
//! (`x_cols = 0`); the latent initialisation is a PCA projector fit on
//! a BOUNDED sample of rows streamed back from the store
//! ([`crate::store::PcaProject`]), so the leader never holds the full
//! image matrix during bring-up — each chunk is projected to its
//! initial q(X) on the way to its worker. Training runs over real
//! worker processes on TCP; the learned embedding is scored by
//! between/within-class scatter against the PCA initialisation, and
//! perf lands in `BENCH_scenario_mnist_lvm.json` for the CI gate.
//!
//! `--scale smoke` (default) is the CI mode; `--scale full` trains on
//! 10k digits (16x more than fig6's large model).

use std::net::TcpListener;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::coordinator::{GlobalOpt, ModelKind, StreamConfig, TrainConfig, Trainer};
use crate::data::{digits, kmeans, pca};
use crate::experiments::{common, scenarios};
use crate::gp::GlobalParams;
use crate::linalg::Matrix;
use crate::store::{PcaProject, RowMapper, ShardedDiskSource, StoreWriter};
use crate::util::cli::Args;
use crate::util::rng::Rng;

struct Dims {
    n: usize,
    workers: usize,
    iters: usize,
    shard_rows: usize,
    chunk_rows: usize,
    /// Rows streamed back from the store to fit the PCA projector.
    pca_sample: usize,
}

pub fn run(args: &Args) -> Result<()> {
    let scale = scenarios::scale(args)?;
    let d = if scale == "smoke" {
        Dims {
            n: 600,
            workers: 2,
            iters: 2,
            shard_rows: 128,
            chunk_rows: 64,
            pca_sample: 600,
        }
    } else {
        Dims {
            n: 10_000,
            workers: 4,
            iters: 30,
            shard_rows: 2_048,
            chunk_rows: 512,
            pca_sample: 2_000,
        }
    };
    let n = args.get_usize("n", d.n)?;
    let workers = args.get_usize("workers", d.workers)?;
    let iters = args.get_usize("iters", d.iters)?;
    let shard_rows = args.get_usize("shard-rows", d.shard_rows)?;
    let chunk_rows = args.get_usize("chunk-rows", d.chunk_rows)?;
    let pca_sample = args.get_usize("pca-sample", d.pca_sample)?.min(n);
    let seed = args.get_usize("seed", 0)? as u64;
    let out = common::results_dir(args);

    println!(
        "mnist-lvm scenario ({scale}): n={n} digit images, {workers} worker processes, \
         {iters} iters, shard_rows={shard_rows}, chunk_rows={chunk_rows}, \
         PCA sample {pca_sample}"
    );

    // ---- pack an outputs-only store (x_cols = 0). The digit
    // generator's RNG is sequential across rows, so the images are
    // generated in one pass; the packer still flushes shard-by-shard.
    let store_dir = out.join(format!("mnist_lvm_store_{scale}"));
    std::fs::remove_dir_all(&store_dir).ok();
    let t0 = Instant::now();
    let data = digits::generate(n, 0.02, seed);
    let mut w = StoreWriter::create(&store_dir, 0, shard_rows, Some("digits"))?;
    let mut row = 0usize;
    while row < n {
        let rows = chunk_rows.min(n - row);
        let chunk = Matrix::from_fn(rows, digits::PIXELS, |i, j| data.y[(row + i, j)]);
        w.append(&chunk)?;
        row += rows;
    }
    let man = w.finish()?;
    let pack_secs = t0.elapsed().as_secs_f64();
    drop(data); // from here on everything reads from the store
    println!(
        "  packed {} rows x {} px into {} shard(s) at {} ({pack_secs:.2}s)",
        man.n,
        man.dims,
        man.shards.len(),
        store_dir.display()
    );

    // ---- latent initialisation: PCA on a bounded sample streamed
    // back from the store, then a fixed per-row projector for the
    // full streaming bring-up (paper §4.1 initialisation, out-of-core)
    let src = ShardedDiskSource::open(&store_dir)?;
    let art = common::manifest(args)?.config("digits")?.clone();
    ensure!(
        art.d == digits::PIXELS,
        "digits artifact renders {} outputs but the store rows have {} pixels",
        art.d,
        digits::PIXELS
    );
    let mut sample = Matrix::zeros(pca_sample, digits::PIXELS);
    src.stream_range(0, pca_sample, chunk_rows, &mut |row0, chunk| {
        for i in 0..chunk.rows() {
            sample.row_mut(row0 + i).copy_from_slice(chunk.row(i));
        }
        Ok(())
    })?;
    let fit = pca::pca(&sample, art.q, 50, seed ^ 0xACE);
    let sample_latents = pca::whitened_scores(&fit);
    let mut rng = Rng::new(seed);
    let z = kmeans::inducing_init(&sample_latents, art.m, 0.05, &mut rng);
    let mapper = PcaProject::from_pca(&fit, 0.5);
    let params = GlobalParams {
        z,
        log_ls: vec![0.0; art.q],
        log_sf2: 0.0,
        log_beta: 1.0,
    };

    // the PCA-initialised embedding over ALL rows (streamed through
    // the same projector) — the baseline the trained embedding must beat
    let labels: Vec<usize> = (0..n).map(|i| i % 10).collect();
    let mut init_latents = Matrix::zeros(n, art.q);
    src.stream_range(0, n, chunk_rows, &mut |row0, chunk| {
        let (xmu, _, _) = mapper.map(row0, chunk)?;
        for i in 0..xmu.rows() {
            init_latents.row_mut(row0 + i).copy_from_slice(xmu.row(i));
        }
        Ok(())
    })?;
    let sep_init = common::class_separation(&init_latents, &labels);
    drop(init_latents);

    // ---- bring-up over real worker processes, streamed from the store
    let art_dir = common::artifacts_dir(args);
    let listener = TcpListener::bind("127.0.0.1:0").context("binding the leader listener")?;
    let addr = listener.local_addr()?.to_string();
    let procs = scenarios::spawn_workers(workers, &addr, &art_dir)?;
    let cfg = TrainConfig {
        artifact: "digits".into(),
        artifacts_dir: art_dir,
        workers,
        model: ModelKind::Lvm,
        global_opt: GlobalOpt::Scg,
        math_mode: common::math_mode(args)?,
        fill_threads: common::fill_threads(args)?,
        seed,
        ..Default::default()
    };
    let stream = StreamConfig {
        source: &src,
        mapper: &mapper,
        chunk_rows,
        kl_weight: 1.0,
        shard_refs: None,
    };
    let mut t = Trainer::accept_tcp_streaming(cfg, params, &stream, &listener)?;
    println!(
        "  cluster up in {:.2}s (streamed bring-up, leader holds <= {chunk_rows} rows)",
        t.log.startup_secs
    );

    let mut bound = f64::NAN;
    let mut train_secs = 0.0;
    for i in 0..iters {
        let ti = Instant::now();
        bound = t.step()?;
        let secs = ti.elapsed().as_secs_f64();
        train_secs += secs;
        println!(
            "  iter {i:>3}: F = {bound:.4}  ({secs:.2}s, {:.0} rows/s)",
            n as f64 / secs.max(1e-9)
        );
    }

    // ---- score the learned embedding against the PCA baseline
    let trained = common::gathered_xmu(&mut t, art.q)?;
    let sep_trained = common::class_separation(&trained, &labels);
    let relevance = common::ard_relevance(&t.params);
    let active = relevance.iter().filter(|r| **r > 0.1).count();
    println!(
        "  class separation: PCA init {sep_init:.4} -> trained {sep_trained:.4}; \
         {active}/{} latent dims active (ARD)",
        art.q
    );

    let report = scenarios::ScenarioReport {
        scenario: "mnist_lvm",
        scale: scale.into(),
        shape: vec![
            ("n", n),
            ("workers", workers),
            ("iters", iters),
            ("shard_rows", shard_rows),
            ("chunk_rows", chunk_rows),
            ("m", art.m),
            ("q", art.q),
        ],
        series: vec![
            ("pack_ns_per_row", scenarios::ns_per_row(pack_secs, n)),
            ("train_ns_per_row", scenarios::ns_per_row(train_secs, n * iters)),
        ],
        info: vec![
            ("train_rows_per_sec", (n * iters) as f64 / train_secs.max(1e-9)),
            ("class_separation_init", sep_init),
            ("class_separation_trained", sep_trained),
            ("final_bound", bound),
        ],
    };
    let path = scenarios::write_report(&out, &report)?;
    println!("  report -> {}", path.display());
    drop(t);
    drop(procs);
    Ok(())
}
