//! Fig. 1: the synthetic 1D-latent dataset and its lower-dimensional
//! embedding by the GPLVM vs PCA.
//!
//! Paper shows the 3D sample (left), the GPLVM embedding (centre) and
//! PCA (right). The quantitative form we print: correlation between the
//! recovered dominant latent dimension and the true 1D latent, for both
//! methods, plus the ARD relevances showing the GPLVM discovered the
//! intrinsic dimensionality.

use anyhow::Result;

use crate::data::{pca, synthetic};
use crate::experiments::common;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::stats;

pub fn run(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 100)?;
    let iters = args.get_usize("iters", 60)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let data = synthetic::generate(n, 0.05, seed);

    // --- GPLVM via the distributed coordinator (small artifact q=2) ----
    let (mut trainer, _init) =
        common::lvm_trainer(args, "small", &data.y, 16, 2, 2, seed)?;
    let f0 = trainer.evaluate()?;
    let f1 = trainer.train(iters)?;
    let xmu = common::gathered_xmu(&mut trainer, 2)?;
    let ard = common::ard_relevance(&trainer.params);

    // dominant latent dimension: ARD relevance weighted by the empirical
    // variance of the latent coordinates (early in training the variances
    // reflect the switch-off before the lengthscales fully adapt)
    let var_of = |d: usize| {
        let col: Vec<f64> = (0..n).map(|i| xmu[(i, d)]).collect();
        stats::std_dev(&col).powi(2)
    };
    let dom = if ard[0] * var_of(0) >= ard[1] * var_of(1) { 0 } else { 1 };
    let gplvm_dim: Vec<f64> = (0..n).map(|i| xmu[(i, dom)]).collect();
    let r_gplvm = stats::pearson(&data.latent, &gplvm_dim).abs();

    // --- PCA baseline ----------------------------------------------------
    let p = pca::pca(&data.y, 2, 60, seed ^ 1);
    let pca_dim: Vec<f64> = (0..n).map(|i| p.scores[(i, 0)]).collect();
    let r_pca = stats::pearson(&data.latent, &pca_dim).abs();

    println!("fig1: synthetic 1D latent -> 3D observations, n={n}");
    println!("  GPLVM bound: {f0:.2} -> {f1:.2} over {iters} iterations");
    println!("  ARD relevances (normalised): {ard:.3?}  (dominant dim {dom})");
    println!("  |corr(true latent, GPLVM dim{dom})| = {r_gplvm:.4}");
    println!("  |corr(true latent, PCA pc1)|       = {r_pca:.4}");
    println!("  paper claim: GPLVM recovers the 1D structure (non-linear map),");
    println!("  PCA captures it only up to the linear component.");

    let mut csv = CsvWriter::new(&["true_latent", "gplvm_x1", "gplvm_x2", "pca_1", "pca_2"]);
    for i in 0..n {
        csv.row(&[
            data.latent[i],
            xmu[(i, 0)],
            xmu[(i, 1)],
            p.scores[(i, 0)],
            p.scores[(i, 1)],
        ]);
    }
    let path = common::results_dir(args).join("fig1_embedding.csv");
    csv.save(&path)?;
    println!("  series -> {}", path.display());
    Ok(())
}
