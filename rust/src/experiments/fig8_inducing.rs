//! Fig. 8: negative log-likelihood as a function of the location of a
//! single inducing point z — with q(u) FIXED (top panel, the SVI
//! setting) vs with q(u) the analytic optimum as a function of z
//! (bottom panel, this paper's collapsed setting).
//!
//! The paper's point (§6): a local minimum over z under fixed q(u) is
//! not necessarily a minimum when q(u) is re-optimised, which is why
//! SVI has to pin the inducing locations while the collapsed
//! re-parametrisation can optimise them jointly.

use anyhow::Result;

use crate::baselines::svi::{optimal_qu, svi_bound};
use crate::gp::{self, kernel, GlobalParams};
use crate::linalg::Matrix;
use crate::experiments::common;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;

pub fn run(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 120)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let grid = args.get_usize("grid", 81)?;
    let jitter = 1e-8;

    // 1D regression data with structure away from the moving point
    let mut rng = Rng::new(seed);
    let x = Matrix::from_fn(n, 1, |_, _| rng.range(-3.0, 3.0));
    let y = Matrix::from_fn(n, 1, |i, _| {
        (1.5 * x[(i, 0)]).sin() + 0.1 * rng.normal()
    });
    // the moving point z0 starts REDUNDANT (next to the -2.5 point) while
    // the region [1.5, 3] has no inducing coverage: the collapsed bound
    // wants to move z0 there, but a q(u) frozen at the initial
    // configuration has no sensible value for u_0 at such a location —
    // its landscape keeps z0 near where it was solved.
    let base = GlobalParams {
        z: Matrix::from_vec(5, 1, vec![-2.0, -2.5, -1.2, -0.2, 0.8]),
        log_ls: vec![(0.6_f64).ln()],
        log_sf2: 0.0,
        log_beta: (100.0_f64).ln(),
    };
    let xvar = Matrix::zeros(n, 1);
    let mask = vec![1.0; n];

    // freeze q(u) at the optimum for the INITIAL configuration
    let stats0 = kernel::shard_stats(&base, &x, &xvar, &y, &mask, 0.0);
    let qu_fixed = optimal_qu(&base, &stats0, jitter)?;

    let mut csv = CsvWriter::new(&["z0", "nll_fixed_qu", "nll_optimal_qu"]);
    let mut best_fixed = (f64::INFINITY, 0.0);
    let mut best_free = (f64::INFINITY, 0.0);
    for g in 0..grid {
        let z0 = -3.0 + 6.0 * g as f64 / (grid - 1) as f64;
        let mut p = base.clone();
        p.z[(0, 0)] = z0;
        // fixed q(u): Hensman bound at the frozen distribution
        let f_fixed = svi_bound(&p, &qu_fixed, &x, &y, jitter)?;
        // optimal q(u): the collapsed bound re-solves q(u) for each z
        let stats = kernel::shard_stats(&p, &x, &xvar, &y, &mask, 0.0);
        let kmm = kernel::kmm(&p, jitter);
        let (bv, _) = gp::assemble_bound(&stats, &kmm, p.log_beta, 1)?;
        let (nll_fixed, nll_free) = (-f_fixed, -bv.f);
        if nll_fixed < best_fixed.0 {
            best_fixed = (nll_fixed, z0);
        }
        if nll_free < best_free.0 {
            best_free = (nll_free, z0);
        }
        csv.row(&[z0, nll_fixed, nll_free]);
    }

    println!("fig8: NLL vs location of inducing point z0 (grid of {grid})");
    println!(
        "  fixed q(u):   min NLL {:.3} at z0 = {:.2}",
        best_fixed.0, best_fixed.1
    );
    println!(
        "  optimal q(u): min NLL {:.3} at z0 = {:.2}",
        best_free.0, best_free.1
    );
    println!(
        "  minima {}  (paper: a fixed-q(u) minimum need not be a minimum once\n   q(u) is re-optimised — the collapsed bound can move Z, SVI cannot)",
        if (best_fixed.1 - best_free.1).abs() > 1e-9 {
            "DIFFER"
        } else {
            "coincide on this draw"
        }
    );
    let path = common::results_dir(args).join("fig8_inducing.csv");
    csv.save(&path)?;
    println!("  curves -> {}", path.display());
    Ok(())
}
