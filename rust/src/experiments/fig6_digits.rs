//! §4.5 / Fig. 6: USPS-style digit modelling — train a GPLVM density
//! model over digit images, reconstruct digits with 34% of pixels
//! missing, and quantify the benefit of training on more data
//! (paper: 5.9% lower mean reconstruction error with the full dataset
//! vs a 1000-digit subset).
//!
//! Reconstruction: the test image's latent point is inferred by
//! gradient descent on the squared error over *observed* pixels
//! (analytic dPsi1/dx for the s=0 case), then the model's posterior
//! mean fills the missing pixels.

use anyhow::Result;

use crate::data::digits;
use crate::experiments::common;
use crate::gp::{bound::PosteriorWeights, kernel, GlobalParams};
use crate::linalg::Matrix;
use crate::optim::Adam;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;
use crate::util::stats;

/// Infer the latent point for a partially observed image and return the
/// full predicted image.
pub fn reconstruct(
    params: &GlobalParams,
    weights: &PosteriorWeights,
    train_latents: &Matrix,
    train_images: &Matrix,
    y_obs: &[f64],
    kept: &[bool],
    steps: usize,
) -> Vec<f64> {
    let q = params.q();
    // init: latent of the training image closest on observed pixels
    let mut best = (f64::INFINITY, 0usize);
    for i in 0..train_images.rows() {
        let mut d = 0.0;
        for (p, k) in kept.iter().enumerate() {
            if *k {
                let r = train_images[(i, p)] - y_obs[p];
                d += r * r;
            }
        }
        if d < best.0 {
            best = (d, i);
        }
    }
    let mut x: Vec<f64> = train_latents.row(best.1).to_vec();

    let ls2: Vec<f64> = params.log_ls.iter().map(|l| (2.0 * l).exp()).collect();
    let obs: Vec<usize> = kept
        .iter()
        .enumerate()
        .filter(|(_, k)| **k)
        .map(|(p, _)| p)
        .collect();
    let mut adam = Adam::new(q, 0.05);
    let m = params.m();
    for _ in 0..steps {
        // k(x, Z) row and prediction on observed pixels
        let xm = Matrix::from_vec(1, q, x.clone());
        let k = kernel::seard(&xm, &params.z, params); // 1 x m
        // residuals on observed pixels
        let mut dl_dk = vec![0.0; m];
        for &p in &obs {
            let mut mean_p = 0.0;
            for j in 0..m {
                mean_p += k[(0, j)] * weights.w1[(j, p)];
            }
            let r = 2.0 * (mean_p - y_obs[p]);
            for j in 0..m {
                dl_dk[j] += r * weights.w1[(j, p)];
            }
        }
        // dk_j/dx_t = k_j (z_jt - x_t)/ls2_t ; dL/dx_t = sum_j dl_dk_j dk_j/dx_t
        let grad: Vec<f64> = (0..q)
            .map(|t| {
                let mut s = 0.0;
                for j in 0..m {
                    s += dl_dk[j] * k[(0, j)] * (params.z[(j, t)] - x[t]) / ls2[t];
                }
                s
            })
            .collect();
        adam.step(&mut x, &grad);
    }
    // final full prediction
    let xm = Matrix::from_vec(1, q, x);
    let k = kernel::seard(&xm, &params.z, params);
    let mean = k.matmul(&weights.w1);
    mean.row(0).to_vec()
}

struct TrainedModel {
    params: GlobalParams,
    weights: PosteriorWeights,
    latents: Matrix,
    images: Matrix,
}

fn train_model(args: &Args, n: usize, iters: usize, seed: u64) -> Result<TrainedModel> {
    let data = digits::generate(n, 0.02, seed);
    let (mut t, _) = common::lvm_trainer(args, "digits", &data.y, 48, 8, 4, seed)?;
    t.train(iters)?;
    let weights = t.posterior()?;
    let latents = common::gathered_xmu(&mut t, 8)?;
    Ok(TrainedModel {
        params: t.params.clone(),
        weights,
        latents,
        images: data.y,
    })
}

fn eval_model(model: &TrainedModel, n_test: usize, drop_frac: f64, seed: u64) -> f64 {
    let test = digits::generate(n_test, 0.02, seed ^ 0xDEAD);
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let mut errs = Vec::new();
    for i in 0..n_test {
        let image: Vec<f64> = test.y.row(i).to_vec();
        let (obs, kept) = digits::drop_pixels(&image, drop_frac, &mut rng);
        let rec = reconstruct(
            &model.params,
            &model.weights,
            &model.latents,
            &model.images,
            &obs,
            &kept,
            60,
        );
        // mean abs error over the DROPPED pixels
        let mut e = 0.0;
        let mut c = 0;
        for (p, k) in kept.iter().enumerate() {
            if !*k {
                e += (rec[p] - image[p]).abs();
                c += 1;
            }
        }
        if c > 0 {
            errs.push(e / c as f64);
        }
    }
    stats::mean(&errs)
}

pub fn run(args: &Args) -> Result<()> {
    let n_small = args.get_usize("n-small", 150)?;
    let n_large = args.get_usize("n-large", 600)?;
    let n_test = args.get_usize("n-test", 30)?;
    let iters = args.get_usize("iters", 25)?;
    let drop_frac = args.get_f64("drop", 0.34)?;
    let seed = args.get_usize("seed", 0)? as u64;

    println!(
        "fig6: digit reconstruction with {:.0}% dropped pixels (USPS-like synthetic)",
        drop_frac * 100.0
    );
    let small = train_model(args, n_small, iters, seed)?;
    let err_small = eval_model(&small, n_test, drop_frac, seed);
    println!("  model trained on {n_small} digits: mean reconstruction error {err_small:.4}");
    let large = train_model(args, n_large, iters, seed)?;
    let err_large = eval_model(&large, n_test, drop_frac, seed);
    println!("  model trained on {n_large} digits: mean reconstruction error {err_large:.4}");
    let improvement = (err_small - err_large) / err_small * 100.0;
    println!("  improvement from more data: {improvement:.1}%   (paper: 5.9% with 4.6x more data)");

    let mut csv = CsvWriter::new(&["n_train", "mean_abs_error"]);
    csv.row(&[n_small as f64, err_small]);
    csv.row(&[n_large as f64, err_large]);
    let path = common::results_dir(args).join("fig6_digits.csv");
    csv.save(&path)?;
    println!("  series -> {}", path.display());
    Ok(())
}
