//! Experiment harness: one module per figure/table in the paper's
//! evaluation, each regenerating the corresponding series (printed and
//! saved as CSV under `results/`). See DESIGN.md §4 for the index and
//! EXPERIMENTS.md for paper-vs-measured.

pub mod ablations;
pub mod common;
pub mod fig1_embedding;
pub mod fig2_core_scaling;
pub mod fig3_data_scaling;
pub mod fig4_oilflow;
pub mod fig5_load;
pub mod fig6_digits;
pub mod fig7_failure;
pub mod fig8_inducing;
pub mod scenario_flights;
pub mod scenario_mnist_lvm;
pub mod scenarios;

use anyhow::{bail, Result};

use crate::util::cli::Args;

/// Run one experiment by name (or `all`).
pub fn run(name: &str, args: &Args) -> Result<()> {
    match name {
        "fig1" => fig1_embedding::run(args),
        "fig2" => fig2_core_scaling::run(args),
        "fig3" => fig3_data_scaling::run(args),
        "fig4" => fig4_oilflow::run(args),
        "fig5" => fig5_load::run(args),
        "fig6" => fig6_digits::run(args),
        "fig7" => fig7_failure::run(args),
        "fig8" => fig8_inducing::run(args),
        "ablations" => ablations::run(args),
        // the paper-scale out-of-core scenarios (DESIGN.md §13) spawn
        // real worker processes — deliberately NOT part of `all`
        "flights" => scenario_flights::run(args),
        "mnist-lvm" => scenario_mnist_lvm::run(args),
        "all" => {
            for f in [
                "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8",
            ] {
                println!("\n================ {f} ================");
                run(f, args)?;
            }
            Ok(())
        }
        other => bail!(
            "unknown experiment {other:?} (fig1..fig8, ablations, flights, mnist-lvm or all)"
        ),
    }
}
