//! Shared plumbing for the experiment harness and the CLI: results /
//! artifact directories, the single parse site for every repeated
//! flag (`--math-mode`, `--fill-threads`, `--listen`, `--connect`,
//! `--interval-ms`), and LVM initialisation.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use crate::data::{kmeans, pca};
use crate::gp::{GlobalParams, MathMode};
use crate::linalg::Matrix;
use crate::runtime::Manifest;
use crate::util::cli::Args;
use crate::util::rng::Rng;

/// Where experiment CSVs land.
pub fn results_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_str("out", "results"))
}

pub fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(crate::runtime::default_artifacts_dir)
}

pub fn manifest(args: &Args) -> Result<Manifest> {
    Manifest::load(&artifacts_dir(args))
}

/// `--math-mode strict|fast`, when given (the single parse site for
/// the flag: the worker daemon distinguishes "absent" from "pinned").
pub fn math_mode_opt(args: &Args) -> Result<Option<MathMode>> {
    match args.get("math-mode") {
        None => Ok(None),
        Some(s) => MathMode::parse(s)
            .map(Some)
            .ok_or_else(|| anyhow!("--math-mode expects strict|fast, got {s:?}")),
    }
}

/// `--math-mode strict|fast` (default strict — the bit-for-bit policy).
pub fn math_mode(args: &Args) -> Result<MathMode> {
    Ok(math_mode_opt(args)?.unwrap_or_default())
}

/// `--fill-threads N`, when given (the single parse site: the worker
/// daemon distinguishes "absent" from "pinned", like `--math-mode`).
/// Rejects 0 — the wire `Init` carries only counts >= 1 (DESIGN.md §11).
pub fn fill_threads_opt(args: &Args) -> Result<Option<u32>> {
    match args.get("fill-threads") {
        None => Ok(None),
        Some(_) => {
            let n = args.get_usize("fill-threads", 1)?;
            anyhow::ensure!(n >= 1, "--fill-threads must be >= 1 (got {n})");
            Ok(Some(n as u32))
        }
    }
}

/// `--fill-threads N` (default 1 — the sequential psi fill).
pub fn fill_threads(args: &Args) -> Result<usize> {
    Ok(fill_threads_opt(args)?.unwrap_or(1) as usize)
}

/// `--listen ADDR` with HOST:PORT validation — the single parse site
/// for every server command (`serve`, `control`, `lb`, `worker
/// --listen`), so a typo'd address fails with the flag named instead
/// of a bare bind error.
pub fn listen_addr<'a>(args: &'a Args, default: &'a str) -> Result<&'a str> {
    let addr = args.get_str("listen", default);
    validate_addr(addr, "--listen")?;
    Ok(addr)
}

/// `--connect ADDR` (required, single address) with HOST:PORT
/// validation; `what` is the usage line shown when the flag is
/// missing. The single parse site for the client commands (`predict`,
/// `reload`, `stats`, `lb`). The leader-side comma list
/// (`train --connect a,b,c`) parses separately.
pub fn connect_addr<'a>(args: &'a Args, what: &str) -> Result<&'a str> {
    let addr = args.get("connect").ok_or_else(|| anyhow!("{what}"))?;
    validate_addr(addr, "--connect")?;
    Ok(addr)
}

/// Shape check only (host non-empty, port numeric) — resolution
/// happens at bind/dial time. `[::1]:7743` splits at the LAST colon,
/// so bracketed IPv6 hosts pass.
fn validate_addr(addr: &str, flag: &str) -> Result<()> {
    let (host, port) = addr
        .rsplit_once(':')
        .ok_or_else(|| anyhow!("{flag} expects HOST:PORT, got {addr:?}"))?;
    anyhow::ensure!(!host.is_empty(), "{flag} expects HOST:PORT, got {addr:?}");
    anyhow::ensure!(
        port.parse::<u16>().is_ok(),
        "{flag} expects a numeric port in HOST:PORT, got {addr:?}"
    );
    Ok(())
}

/// A millisecond-interval flag as a `Duration` (floor 1ms) — shared by
/// `stats --watch --interval-ms`, the lb membership refresh and the
/// serve fleet heartbeat.
pub fn interval_ms(args: &Args, key: &str, default_ms: usize) -> Result<Duration> {
    Ok(Duration::from_millis(
        args.get_usize(key, default_ms)?.max(1) as u64
    ))
}

/// Standard GPLVM initialisation (paper §4.1): PCA-whitened latents,
/// k-means(+noise) inducing points, unit hypers.
pub struct LvmInit {
    pub params: GlobalParams,
    pub xmu: Matrix,
    pub xvar: Matrix,
}

pub fn lvm_init(y: &Matrix, m: usize, q: usize, seed: u64) -> LvmInit {
    let mut rng = Rng::new(seed);
    let p = pca::pca(y, q, 50, seed ^ 0xACE);
    let xmu = pca::whitened_scores(&p);
    let xvar = Matrix::from_fn(xmu.rows(), q, |_, _| 0.5);
    let z = kmeans::inducing_init(&xmu, m, 0.05, &mut rng);
    LvmInit {
        params: GlobalParams {
            z,
            log_ls: vec![0.0; q],
            log_sf2: 0.0,
            log_beta: 1.0,
        },
        xmu,
        xvar,
    }
}

/// Build a distributed LVM trainer over `workers` nodes.
pub fn lvm_trainer(
    args: &Args,
    artifact: &str,
    y: &Matrix,
    m: usize,
    q: usize,
    workers: usize,
    seed: u64,
) -> Result<(Trainer, LvmInit)> {
    let init = lvm_init(y, m, q, seed);
    let shards = partition(&init.xmu, &init.xvar, y, 1.0, workers);
    let cfg = TrainConfig {
        artifact: artifact.into(),
        artifacts_dir: artifacts_dir(args),
        workers,
        model: ModelKind::Lvm,
        global_opt: GlobalOpt::Scg,
        math_mode: math_mode(args)?,
        fill_threads: fill_threads(args)?,
        seed,
        ..Default::default()
    };
    let trainer = Trainer::new(cfg, init.params.clone(), shards)?;
    Ok((trainer, init))
}

/// ARD relevance per latent dimension: 1/lengthscale^2 normalised to the
/// largest (paper §4.4/§5.2 report which dimensions "switch off").
pub fn ard_relevance(params: &GlobalParams) -> Vec<f64> {
    let inv: Vec<f64> = params.log_ls.iter().map(|l| (-2.0 * l).exp()).collect();
    let max = inv.iter().cloned().fold(f64::MIN, f64::max).max(1e-300);
    inv.iter().map(|v| v / max).collect()
}

/// Gather the full latent means from a trainer, scattered back to
/// **original dataset row order** via the per-row indices the gather
/// returns — correct even after a decommission re-shard moved rows to
/// the survivors' shard tails.
pub fn gathered_xmu(t: &mut Trainer, q: usize) -> Result<Matrix> {
    let locals = t.gather_locals()?;
    let n: usize = locals.iter().map(|(_, mu, _)| mu.rows()).sum();
    let mut out = Matrix::zeros(n, q);
    for (ids, mu, _) in &locals {
        for (i, &orig) in ids.iter().enumerate() {
            anyhow::ensure!(orig < n, "gathered row index {orig} out of range (n={n})");
            out.row_mut(orig).copy_from_slice(mu.row(i));
        }
    }
    Ok(out)
}

/// Between-class / within-class scatter ratio of a labelled embedding —
/// the separation metric used to compare latent spaces (Fig. 4).
pub fn class_separation(x: &Matrix, labels: &[usize]) -> f64 {
    let n = x.rows();
    let q = x.cols();
    let k = labels.iter().copied().max().unwrap_or(0) + 1;
    let mut means = vec![vec![0.0; q]; k];
    let mut counts = vec![0usize; k];
    let mut grand = vec![0.0; q];
    for i in 0..n {
        counts[labels[i]] += 1;
        for j in 0..q {
            means[labels[i]][j] += x[(i, j)];
            grand[j] += x[(i, j)];
        }
    }
    for j in 0..q {
        grand[j] /= n as f64;
    }
    for c in 0..k {
        for j in 0..q {
            means[c][j] /= counts[c].max(1) as f64;
        }
    }
    let mut between = 0.0;
    for c in 0..k {
        let mut d = 0.0;
        for j in 0..q {
            d += (means[c][j] - grand[j]).powi(2);
        }
        between += counts[c] as f64 * d;
    }
    let mut within = 0.0;
    for i in 0..n {
        for j in 0..q {
            within += (x[(i, j)] - means[labels[i]][j]).powi(2);
        }
    }
    between / within.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ard_relevance_normalised() {
        let p = GlobalParams {
            z: Matrix::zeros(2, 3),
            log_ls: vec![0.0, 1.0, 3.0],
            log_sf2: 0.0,
            log_beta: 0.0,
        };
        let r = ard_relevance(&p);
        assert!((r[0] - 1.0).abs() < 1e-12);
        assert!(r[1] < r[0] && r[2] < r[1]);
    }

    #[test]
    fn class_separation_orders_embeddings() {
        // well separated clusters vs mixed labels
        let x = Matrix::from_fn(40, 2, |i, j| {
            if i < 20 {
                0.0 + 0.05 * (i * 7 % 13) as f64 * if j == 0 { 1.0 } else { -1.0 }
            } else {
                5.0 + 0.05 * (i * 5 % 11) as f64
            }
        });
        let good: Vec<usize> = (0..40).map(|i| usize::from(i >= 20)).collect();
        let bad: Vec<usize> = (0..40).map(|i| i % 2).collect();
        assert!(class_separation(&x, &good) > class_separation(&x, &bad) * 10.0);
    }
}
