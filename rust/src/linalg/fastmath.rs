//! Fast scalar math for the `MathMode::Fast` kernel paths.
//!
//! The psi-statistics hot loops are `exp`-bound: every Psi1 entry and
//! every Psi2 entry ends in one exponential, O(n m^2) of them per
//! evaluation. [`exp`] is a branch-light Cody–Waite / polynomial
//! exponential that trades the libm special-case handling for
//! throughput; [`exp_scale_in_place`] applies it over a whole slice of
//! precomputed exponents (the Fast kernels batch the exponent
//! computation row-wise, then run one exp pass over the block).
//!
//! Accuracy contract: relative error below [`MAX_REL_ERR`] against
//! `f64::exp` on finite inputs in `[-708, 709]` (unit-tested). Inputs
//! below -708 flush to `0.0` — the true value there is at the
//! subnormal boundary (< 1e-307) and the psi accumulations the Fast
//! mode feeds are insensitive to it at the 1e-9 relative tolerance the
//! mode guarantees (DESIGN.md §8). **Never** called from a Strict-mode
//! path: Strict pins `f64::exp`'s exact rounding bit-for-bit.

/// Documented (and tested) relative-error bound of [`exp`] vs libm.
pub const MAX_REL_ERR: f64 = 1e-13;

// Cody–Waite split of ln 2 (fdlibm constants): n * LN2_HI is exact for
// |n| <= 1024, so the reduced argument keeps ~full precision.
const LOG2_E: f64 = std::f64::consts::LOG2_E;
const LN2_HI: f64 = 6.931_471_803_691_238_164_90e-1;
const LN2_LO: f64 = 1.908_214_929_270_587_700_02e-10;

/// Fast `e^x` (see module docs for the accuracy/domain contract).
#[inline]
pub fn exp(x: f64) -> f64 {
    if x < -708.0 {
        return 0.0;
    }
    if x > 709.0 {
        return f64::INFINITY;
    }
    // range reduction: x = n ln2 + r with |r| <= ln2 / 2
    let n = (x * LOG2_E).round();
    let r = (x - n * LN2_HI) - n * LN2_LO;
    // degree-13 Taylor of e^r on |r| <= 0.3466: truncation error
    // ~4e-18, well inside MAX_REL_ERR after Horner rounding
    let mut p = 1.0 / 6_227_020_800.0; // 1/13!
    p = p * r + 1.0 / 479_001_600.0; // 1/12!
    p = p * r + 1.0 / 39_916_800.0;
    p = p * r + 1.0 / 3_628_800.0;
    p = p * r + 1.0 / 362_880.0;
    p = p * r + 1.0 / 40_320.0;
    p = p * r + 1.0 / 5_040.0;
    p = p * r + 1.0 / 720.0;
    p = p * r + 1.0 / 120.0;
    p = p * r + 1.0 / 24.0;
    p = p * r + 1.0 / 6.0;
    p = p * r + 0.5;
    p = p * r + 1.0;
    p = p * r + 1.0;
    // scale by 2^n through the exponent bits: the clamps above keep
    // 1023 + n inside the normal-exponent range [2, 2046]
    p * f64::from_bits(((1023 + n as i64) as u64) << 52)
}

/// `out[i] = scale * exp(out[i])` over a slice — the Fast kernels'
/// batched exponent pass (Strict exps inline, entry by entry, to keep
/// the historical operation order).
#[inline]
pub fn exp_scale_in_place(out: &mut [f64], scale: f64) {
    for x in out.iter_mut() {
        *x = scale * exp(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rel_err(x: f64) -> f64 {
        let reference = x.exp();
        if reference == 0.0 {
            return exp(x).abs();
        }
        ((exp(x) - reference) / reference).abs()
    }

    #[test]
    fn matches_libm_within_bound() {
        // the psi exponents are non-positive; sweep that range densely
        // plus a positive band for the general contract
        let mut rng = Rng::new(77);
        for _ in 0..20_000 {
            let x = -740.0 + 760.0 * rng.uniform();
            if x < -708.0 {
                assert_eq!(exp(x), 0.0, "x={x} must flush to zero");
            } else {
                assert!(rel_err(x) < MAX_REL_ERR, "x={x}: rel err {}", rel_err(x));
            }
        }
        for x in [0.0, -0.0, 1.0, -1.0, 0.5 * std::f64::consts::LN_2, -708.0, 709.0] {
            assert!(rel_err(x) < MAX_REL_ERR, "x={x}: rel err {}", rel_err(x));
        }
        assert_eq!(exp(f64::NEG_INFINITY), 0.0);
        assert_eq!(exp(f64::INFINITY), f64::INFINITY);
    }

    #[test]
    fn slice_pass_applies_scale() {
        let mut v = vec![-1.0, 0.0, -30.0];
        exp_scale_in_place(&mut v, 2.0);
        for (out, x) in v.iter().zip([-1.0f64, 0.0, -30.0]) {
            assert!(((out - 2.0 * x.exp()) / (2.0 * x.exp())).abs() < MAX_REL_ERR);
        }
    }
}
