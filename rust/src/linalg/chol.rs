//! Cholesky factorisation with jitter escalation, triangular solves,
//! log-determinants and SPD inverses — the O(m^3) toolbox of the
//! central node's global step.

use anyhow::{bail, Result};

use super::Matrix;

/// Lower-triangular Cholesky factor of an SPD matrix.
pub struct Cholesky {
    l: Matrix,
    /// jitter that had to be added to the diagonal for success (0 if none).
    pub jitter_used: f64,
}

impl Cholesky {
    /// Factor `a` (symmetric positive definite). Fails if not SPD.
    pub fn new(a: &Matrix) -> Result<Cholesky> {
        match Self::factor(a) {
            Some(l) => Ok(Cholesky { l, jitter_used: 0.0 }),
            None => bail!("matrix is not positive definite"),
        }
    }

    /// Factor with escalating diagonal jitter (the standard GP trick:
    /// start at `base` * mean-diagonal and multiply by 10 up to `tries`
    /// times). Mirrors what GPy/GParML do for nearly singular Kmm.
    pub fn new_with_jitter(a: &Matrix, base: f64, tries: usize) -> Result<Cholesky> {
        if let Some(l) = Self::factor(a) {
            return Ok(Cholesky { l, jitter_used: 0.0 });
        }
        let scale = a.trace() / a.rows() as f64;
        let mut jitter = base * scale.max(1e-300);
        for _ in 0..tries {
            if let Some(l) = Self::factor(&a.add_diag(jitter)) {
                return Ok(Cholesky { l, jitter_used: jitter });
            }
            jitter *= 10.0;
        }
        bail!("cholesky failed even with jitter {jitter:e}")
    }

    fn factor(a: &Matrix) -> Option<Matrix> {
        let n = a.rows();
        assert_eq!(n, a.cols(), "cholesky requires square input");
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // s = a[i][j] - sum_k l[i][k] l[j][k]
                let mut s = a[(i, j)];
                let (li, lj) = (l.row(i), l.row(j));
                for k in 0..j {
                    s -= li[k] * lj[k];
                }
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return None;
                    }
                    l[(i, i)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(l)
    }

    pub fn l(&self) -> &Matrix {
        &self.l
    }

    pub fn dim(&self) -> usize {
        self.l.rows()
    }

    /// log |A| = 2 sum_i log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.dim()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Solve L x = b (forward substitution) for each column of b.
    pub fn solve_lower(&self, b: &Matrix) -> Matrix {
        let n = self.dim();
        assert_eq!(b.rows(), n);
        let mut x = b.clone();
        for i in 0..n {
            for k in 0..i {
                let lik = self.l[(i, k)];
                if lik == 0.0 {
                    continue;
                }
                // x[i] -= l[i][k] * x[k]  (whole row)
                let (head, tail) = x.data_mut().split_at_mut(i * b.cols());
                let xk = &head[k * b.cols()..(k + 1) * b.cols()];
                let xi = &mut tail[..b.cols()];
                for (a, &c) in xi.iter_mut().zip(xk) {
                    *a -= lik * c;
                }
            }
            let d = self.l[(i, i)];
            for v in x.row_mut(i) {
                *v /= d;
            }
        }
        x
    }

    /// Solve L^T x = b (back substitution) for each column of b.
    pub fn solve_upper(&self, b: &Matrix) -> Matrix {
        let n = self.dim();
        assert_eq!(b.rows(), n);
        let mut x = b.clone();
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                let lki = self.l[(k, i)];
                if lki == 0.0 {
                    continue;
                }
                let (head, tail) = x.data_mut().split_at_mut(k * b.cols());
                let xi = &mut head[i * b.cols()..(i + 1) * b.cols()];
                let xk = &tail[..b.cols()];
                for (a, &c) in xi.iter_mut().zip(xk) {
                    *a -= lki * c;
                }
            }
            let d = self.l[(i, i)];
            for v in x.row_mut(i) {
                *v /= d;
            }
        }
        x
    }

    /// Solve A x = b via the factorisation.
    pub fn solve(&self, b: &Matrix) -> Matrix {
        self.solve_upper(&self.solve_lower(b))
    }

    /// A^{-1} (dense).
    pub fn inverse(&self) -> Matrix {
        self.solve(&Matrix::eye(self.dim()))
    }

    /// tr(A^{-1} B).
    pub fn trace_solve(&self, b: &Matrix) -> f64 {
        self.solve(b).trace()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let g = Matrix::from_fn(n, n + 2, |_, _| rng.normal());
        g.matmul_t(&g).add_diag(0.5)
    }

    #[test]
    fn reconstructs_matrix() {
        let a = random_spd(8, 0);
        let ch = Cholesky::new(&a).unwrap();
        let rec = ch.l().matmul_t(ch.l());
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn solve_matches_direct() {
        let a = random_spd(6, 1);
        let mut rng = Rng::new(2);
        let b = Matrix::from_fn(6, 3, |_, _| rng.normal());
        let ch = Cholesky::new(&a).unwrap();
        let x = ch.solve(&b);
        assert!(a.matmul(&x).max_abs_diff(&b) < 1e-10);
    }

    #[test]
    fn log_det_matches_2x2() {
        let a = Matrix::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.log_det() - (11.0_f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(5, 3);
        let inv = Cholesky::new(&a).unwrap().inverse();
        assert!(a.matmul(&inv).max_abs_diff(&Matrix::eye(5)) < 1e-10);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigvals 3, -1
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn jitter_escalation_recovers_singular() {
        // rank-deficient PSD matrix
        let g = Matrix::from_vec(3, 1, vec![1.0, 2.0, 3.0]);
        let a = g.matmul_t(&g);
        assert!(Cholesky::new(&a).is_err());
        let ch = Cholesky::new_with_jitter(&a, 1e-10, 12).unwrap();
        assert!(ch.jitter_used > 0.0);
    }

    #[test]
    fn trace_solve_matches_explicit() {
        let a = random_spd(4, 5);
        let b = random_spd(4, 6);
        let ch = Cholesky::new(&a).unwrap();
        let explicit = ch.inverse().matmul(&b).trace();
        assert!((ch.trace_solve(&b) - explicit).abs() < 1e-10);
    }
}
