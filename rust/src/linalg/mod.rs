//! Dense f64 linear algebra for the constant-size global step and the
//! native baselines.
//!
//! The global step of the paper's algorithm is O(m^3) in the number of
//! inducing points (m ~ 10..200), so a compact, cache-friendly
//! implementation is ample: the heavy O(n m^2 q) work lives in the AOT
//! Pallas/HLO artifacts executed by the workers.

mod chol;
pub mod fastmath;
mod matrix;

pub use chol::Cholesky;
pub use matrix::Matrix;
