//! Row-major dense f64 matrix.

use std::fmt;
use std::ops::{Index, IndexMut};

/// A dense row-major matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Reshape in place to `rows x cols` with every entry set to
    /// `fill`, reusing the existing allocation when it is big enough.
    /// The workspace primitive the `_into` APIs below build on: a hot
    /// loop can own one `Matrix` and reset it every round instead of
    /// allocating a fresh one.
    pub fn reset(&mut self, rows: usize, cols: usize, fill: f64) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, fill);
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// out = self^T into a caller-owned buffer (reshaped as needed).
    pub fn transpose_into(&self, out: &mut Matrix) {
        out.reset(self.cols, self.rows, 0.0);
        for i in 0..self.rows {
            for (j, &v) in self.row(i).iter().enumerate() {
                out[(j, i)] = v;
            }
        }
    }

    /// C = self * other  (ikj loop order, inner loop vectorisable).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// out = self * other into a caller-owned buffer. Identical loop
    /// order (and therefore bit-identical results) to [`Self::matmul`].
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        out.reset(self.rows, other.cols, 0.0);
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for (k, &aik) in a_row.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let b_row = other.row(k);
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += aik * b;
                }
            }
        }
    }

    /// C = self^T * other.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.cols, other.cols);
        self.t_matmul_into(other, &mut out);
        out
    }

    /// out = self^T * other into a caller-owned buffer.
    pub fn t_matmul_into(&self, other: &Matrix, out: &mut Matrix) {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        out.reset(self.cols, other.cols, 0.0);
        for k in 0..self.rows {
            let a_row = self.row(k);
            let b_row = other.row(k);
            for (i, &aki) in a_row.iter().enumerate() {
                if aki == 0.0 {
                    continue;
                }
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += aki * b;
                }
            }
        }
    }

    /// C = self * other^T.
    pub fn matmul_t(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.rows);
        self.matmul_t_into(other, &mut out);
        out
    }

    /// out = self * other^T into a caller-owned buffer (row-dot-row; the
    /// shape the gradient round uses for the Psi1 adjoint `Y (dF/dC)^T`).
    pub fn matmul_t_into(&self, other: &Matrix, out: &mut Matrix) {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        out.reset(self.rows, other.rows, 0.0);
        for i in 0..self.rows {
            let a_row = self.row(i);
            for j in 0..other.rows {
                let b_row = other.row(j);
                let mut s = 0.0;
                for (&a, &b) in a_row.iter().zip(b_row) {
                    s += a * b;
                }
                out[(i, j)] = s;
            }
        }
    }

    /// y = self * x for a vector x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        self.zip(other, |a, b| a - b)
    }

    pub fn scale(&self, s: f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Elementwise combine.
    pub fn zip(&self, other: &Matrix, f: impl Fn(f64, f64) -> f64) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    /// In-place self += s * other.
    pub fn axpy(&mut self, s: f64, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Add s to the diagonal (jitter).
    pub fn add_diag(&self, s: f64) -> Matrix {
        assert_eq!(self.rows, self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            out[(i, i)] += s;
        }
        out
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Frobenius inner product <self, other>.
    pub fn dot(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    /// (A + A^T)/2 — used to keep adjoints exactly symmetric.
    pub fn symmetrize(&self) -> Matrix {
        assert_eq!(self.rows, self.cols);
        Matrix::from_fn(self.rows, self.cols, |i, j| {
            0.5 * (self[(i, j)] + self[(j, i)])
        })
    }

    /// Stack two matrices vertically (same column count).
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "vstack column mismatch");
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Matrix {
            rows: self.rows + other.rows,
            cols: self.cols,
            data,
        }
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |m, x| m.max(x.abs()))
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0_f64, |m, (a, b)| m.max((a - b).abs()))
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn transposed_products_agree() {
        let a = Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f64 * 0.5 - 1.0);
        let b = Matrix::from_fn(4, 5, |i, j| (i + j) as f64 * 0.25);
        let c1 = a.t_matmul(&b);
        let c2 = a.transpose().matmul(&b);
        assert!(c1.max_abs_diff(&c2) < 1e-14);
        let d = Matrix::from_fn(6, 3, |i, j| ((i * j) as f64).sin());
        let e1 = a.matmul_t(&d);
        let e2 = a.matmul(&d.transpose());
        assert!(e1.max_abs_diff(&e2) < 1e-14);
    }

    #[test]
    fn identity_is_neutral() {
        let a = Matrix::from_fn(3, 3, |i, j| (i + 2 * j) as f64);
        assert!(a.matmul(&Matrix::eye(3)).max_abs_diff(&a) == 0.0);
        assert!(Matrix::eye(3).matmul(&a).max_abs_diff(&a) == 0.0);
    }

    #[test]
    fn trace_and_dot() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        assert_eq!(a.trace(), 5.0);
        assert_eq!(a.dot(&a), 30.0);
        // tr(A^T B) == <A, B>
        let b = Matrix::from_vec(2, 2, vec![5., 6., 7., 8.]);
        assert!((a.t_matmul(&b).trace() - a.dot(&b)).abs() < 1e-14);
    }

    #[test]
    fn symmetrize() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 4., 3.]);
        let s = a.symmetrize();
        assert_eq!(s[(0, 1)], 3.0);
        assert_eq!(s[(1, 0)], 3.0);
    }

    #[test]
    fn vstack_concatenates_rows() {
        let a = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Matrix::from_vec(1, 2, vec![5., 6.]);
        let c = a.vstack(&b);
        assert_eq!((c.rows(), c.cols()), (3, 2));
        assert_eq!(c.row(2), &[5., 6.]);
    }

    #[test]
    fn into_variants_match_allocating_variants_bitwise() {
        let a = Matrix::from_fn(4, 3, |i, j| ((i * 7 + j * 3) as f64).sin());
        let b = Matrix::from_fn(3, 5, |i, j| ((i + j * 2) as f64).cos());
        let c = Matrix::from_fn(4, 5, |i, j| (i as f64) - 0.7 * (j as f64));
        // start each workspace deliberately mis-shaped and dirty
        let mut ws = Matrix::from_fn(2, 2, |_, _| f64::NAN);
        a.matmul_into(&b, &mut ws);
        assert_eq!(ws, a.matmul(&b));
        a.t_matmul_into(&c, &mut ws);
        assert_eq!(ws, a.t_matmul(&c));
        c.matmul_t_into(&a, &mut ws);
        assert_eq!(ws, c.matmul_t(&a));
        a.transpose_into(&mut ws);
        assert_eq!(ws, a.transpose());
    }

    #[test]
    fn reset_reshapes_and_fills() {
        let mut m = Matrix::from_fn(5, 5, |_, _| 3.0);
        m.reset(2, 3, 1.5);
        assert_eq!((m.rows(), m.cols()), (2, 3));
        assert!(m.data().iter().all(|&v| v == 1.5));
        m.reset(4, 4, 0.0);
        assert_eq!(m, Matrix::zeros(4, 4));
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_fn(3, 4, |i, j| (i as f64) - (j as f64) * 0.3);
        let x = vec![1.0, -2.0, 0.5, 3.0];
        let y = a.matvec(&x);
        let xm = Matrix::from_vec(4, 1, x);
        let ym = a.matmul(&xm);
        for i in 0..3 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-14);
        }
    }
}
