//! End-to-end tests of the observability layer (DESIGN.md §10): the
//! trace JSONL sink emits parseable records with the documented schema,
//! a client-issued request id round-trips through the wire protocol
//! into server-side spans, the `ServeStats` control frame reports
//! request counts that match the requests actually issued, and strict
//! training stays bit-identical with tracing enabled.

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::Mutex;

use gparml::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use gparml::gp::GlobalParams;
use gparml::linalg::Matrix;
use gparml::model::{serve, Predictor, ServeOptions, ServeState, TrainedModel};
use gparml::obs;
use gparml::util::json::Json;
use gparml::util::rng::Rng;

/// The trace recorder is process-global; tests that enable it must not
/// overlap (cargo runs tests in this binary on parallel threads).
static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gparml_obs_{}_{name}", std::process::id()))
}

fn regression_data(n: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let xmu = Matrix::from_fn(n, 2, |_, _| rng.range(-2.0, 2.0));
    let xvar = Matrix::zeros(n, 2);
    let y = Matrix::from_fn(n, 3, |i, j| {
        let x = xmu[(i, 0)];
        let f = match j {
            0 => x.sin(),
            1 => (1.3 * x).cos(),
            _ => 0.5 * x,
        };
        f + 0.05 * rng.normal()
    });
    (xmu, xvar, y)
}

/// Train a tiny strict regression cluster and export its model.
fn train_and_export(seed: u64, iters: usize) -> TrainedModel {
    let (xmu, xvar, y) = regression_data(60, seed);
    let shards = partition(&xmu, &xvar, &y, 0.0, 2);
    let mut rng = Rng::new(seed + 1);
    let params = GlobalParams {
        z: Matrix::from_fn(8, 2, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let cfg = TrainConfig {
        artifact: "test".into(),
        artifacts_dir: artifacts_dir(),
        workers: 2,
        model: ModelKind::Regression,
        global_opt: GlobalOpt::Scg,
        seed: 1,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg, params, shards).unwrap();
    t.train(iters).unwrap();
    t.export_model().unwrap()
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: diverged at {i}: {x} vs {y}");
    }
}

/// Parse every line of a trace file; each record must carry the
/// documented schema keys. Returns the parsed records.
fn read_trace(path: &std::path::Path) -> Vec<Json> {
    let text = std::fs::read_to_string(path).expect("reading trace file");
    text.lines()
        .map(|line| {
            let rec = Json::parse(line)
                .unwrap_or_else(|e| panic!("trace line is not JSON: {e:#}\n{line}"));
            let ev = rec.get("ev").unwrap().as_str().unwrap().to_string();
            assert!(
                ev == "span" || ev == "event",
                "unknown record kind {ev:?}: {line}"
            );
            rec.get("name").unwrap().as_str().unwrap();
            rec.get("id").unwrap().as_f64().unwrap();
            rec.get("ts_ns").unwrap().as_f64().unwrap();
            rec.get("tid").unwrap().as_f64().unwrap();
            if ev == "span" {
                rec.get("dur_ns").unwrap().as_f64().unwrap();
            }
            rec
        })
        .collect()
}

fn has_record(records: &[Json], name: &str, id: Option<u64>) -> bool {
    records.iter().any(|r| {
        let name_ok = r.opt("name").and_then(|n| n.as_str().ok()) == Some(name);
        let id_ok = match id {
            None => true,
            Some(want) => r.opt("id").and_then(|v| v.as_f64().ok()) == Some(want as f64),
        };
        name_ok && id_ok
    })
}

/// Strict training must be bit-identical with tracing enabled, and the
/// trace it writes must be schema-valid JSONL containing the training
/// span taxonomy tagged with evaluation versions.
#[test]
fn strict_training_is_bit_identical_under_tracing_and_trace_is_valid() {
    let plain = train_and_export(11, 3);

    let _g = TRACE_LOCK.lock().unwrap();
    let path = tmp_path("train_trace.jsonl");
    obs::trace::init(&path).unwrap();
    let traced = train_and_export(11, 3);
    obs::trace::disable();

    assert_bits_eq(
        plain.weights.qu_mean.data(),
        traced.weights.qu_mean.data(),
        "qu_mean",
    );
    assert_bits_eq(
        plain.weights.qu_cov.data(),
        traced.weights.qu_cov.data(),
        "qu_cov",
    );
    assert_bits_eq(plain.weights.w1.data(), traced.weights.w1.data(), "w1");
    assert_eq!(
        plain.meta.final_bound.to_bits(),
        traced.meta.final_bound.to_bits(),
        "final bound diverged under tracing: {} vs {}",
        plain.meta.final_bound,
        traced.meta.final_bound
    );

    let records = read_trace(&path);
    assert!(!records.is_empty(), "traced training wrote no records");
    for name in ["stats_round", "grads_round", "global_step"] {
        assert!(
            has_record(&records, name, None),
            "trace is missing the {name} span"
        );
    }
    // rounds are tagged with the (1-based) evaluation version
    assert!(
        has_record(&records, "stats_round", Some(1)),
        "first stats round should carry evaluation version 1"
    );
    let _ = std::fs::remove_file(&path);
}

/// A live server answers `ServeStats` inline with request counts that
/// match the requests issued, queue/model gauges, and a populated
/// request-latency histogram.
#[test]
fn serve_stats_snapshot_matches_issued_requests() {
    let model = train_and_export(23, 2);
    let state = ServeState::new(Predictor::new(&model).unwrap());
    let opts = ServeOptions {
        max_clients: 1,
        workers: 1,
        max_batch_rows: 4096,
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let mut rng = Rng::new(5);
    let xt_mu = Matrix::from_fn(16, 2, |_, _| rng.range(-2.0, 2.0));
    let xt_var = Matrix::from_fn(16, 2, |_, _| 0.05 * rng.uniform());

    const PREDICTS: usize = 3;
    let snapshot = std::thread::scope(|s| {
        let server = s.spawn(|| serve::serve(&listener, &state, &opts).unwrap());
        let mut client = serve::ServeClient::connect(&addr).unwrap();
        client.model_info().unwrap();
        for _ in 0..PREDICTS {
            client.predict(&xt_mu, &xt_var).unwrap();
        }
        let snapshot = client.stats().unwrap();
        client.hangup();
        server.join().unwrap();
        snapshot
    });

    let json = Json::parse(&snapshot).expect("stats snapshot is JSON");
    let counters = json.get("counters").unwrap().as_obj().unwrap().clone();
    let counter = |name: &str| -> f64 {
        counters
            .get(name)
            .unwrap_or_else(|| panic!("snapshot missing counter {name}"))
            .as_f64()
            .unwrap()
    };
    assert_eq!(counter("serve.requests.predict"), PREDICTS as f64);
    assert_eq!(counter("serve.requests.model_info"), 1.0);
    // the scrape itself is counted before the snapshot is taken
    assert_eq!(counter("serve.requests.stats"), 1.0);
    assert!(counter("serve.batches") >= 1.0);

    let gauges = json.get("gauges").unwrap().as_obj().unwrap().clone();
    assert_eq!(gauges["serve.model_version"].as_f64().unwrap(), 1.0);
    assert_eq!(gauges["serve.queue_depth"].as_f64().unwrap(), 0.0);

    let hist = json
        .get("histograms")
        .unwrap()
        .get("serve.request_ns")
        .unwrap()
        .clone();
    assert_eq!(
        hist.get("count").unwrap().as_f64().unwrap(),
        PREDICTS as f64,
        "every predict should land one request-latency sample"
    );
    assert!(
        hist.get("p50").unwrap().as_f64().unwrap() > 0.0,
        "non-empty histogram must report p50"
    );
}

/// The acceptance criterion: a single request id issued by the client
/// side of `gparml predict --connect` is traceable end-to-end — the id
/// returned by `ServeClient::predict_traced` shows up on the server's
/// enqueue/reply events and batch span after crossing a real TCP
/// round-trip through the framed wire codec.
#[test]
fn client_request_id_round_trips_into_server_spans() {
    let model = train_and_export(31, 2);
    let state = ServeState::new(Predictor::new(&model).unwrap());
    let opts = ServeOptions {
        max_clients: 1,
        workers: 1,
        max_batch_rows: 4096,
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let mut rng = Rng::new(6);
    let xt_mu = Matrix::from_fn(8, 2, |_, _| rng.range(-2.0, 2.0));
    let xt_var = Matrix::from_fn(8, 2, |_, _| 0.05 * rng.uniform());

    let _g = TRACE_LOCK.lock().unwrap();
    let path = tmp_path("serve_trace.jsonl");
    obs::trace::init(&path).unwrap();
    let trace_id = std::thread::scope(|s| {
        let server = s.spawn(|| serve::serve(&listener, &state, &opts).unwrap());
        let mut client = serve::ServeClient::connect(&addr).unwrap();
        let (_, _, trace_id) = client.predict_traced(&xt_mu, &xt_var).unwrap();
        client.hangup();
        server.join().unwrap();
        trace_id
    });
    obs::trace::disable();

    assert_ne!(trace_id, 0, "client must mint a non-zero request id");
    let records = read_trace(&path);
    for name in ["serve_enqueue", "serve_reply"] {
        assert!(
            has_record(&records, name, Some(trace_id)),
            "server trace has no {name} event for request {trace_id:#x}"
        );
    }
    assert!(
        has_record(&records, "serve_batch", Some(trace_id)),
        "the kernel batch span should be tagged with the lead request id"
    );
    let _ = std::fs::remove_file(&path);
}
