//! Fleet integration tests (DESIGN.md §12): a REAL `gparml control`
//! process, two REAL `gparml serve` replica processes and a REAL
//! `gparml lb` front door over localhost TCP. A predict answered
//! through the front door must be bit-identical to local prediction
//! and to a direct replica answer; SIGKILLing a replica mid-stream
//! must stay invisible to a no-retry client (the lb fails over to the
//! sibling); a single `reload` at the front door must roll the whole
//! fleet to the new model version; and the control plane must evict
//! the killed replica by heartbeat staleness.

use std::io::{BufRead, BufReader};
use std::net::TcpListener;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use gparml::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use gparml::fleet::{run_lb, ControlClient, LbOptions, Upstream};
use gparml::gp::GlobalParams;
use gparml::linalg::Matrix;
use gparml::model::{serve, Predictor, ServeClient, ServeOptions, ServeState, TrainedModel};
use gparml::util::json::Json;
use gparml::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gparml_fleet_{}_{name}", std::process::id()))
}

fn regression_data(n: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let xmu = Matrix::from_fn(n, 2, |_, _| rng.range(-2.0, 2.0));
    let xvar = Matrix::zeros(n, 2);
    let y = Matrix::from_fn(n, 3, |i, j| {
        let x = xmu[(i, 0)];
        let f = match j {
            0 => x.sin(),
            1 => (1.3 * x).cos(),
            _ => 0.5 * x,
        };
        f + 0.05 * rng.normal()
    });
    (xmu, xvar, y)
}

/// Train a tiny regression cluster and export its model.
fn trained_model(seed: u64, iters: usize) -> TrainedModel {
    let (xmu, xvar, y) = regression_data(60, seed);
    let shards = partition(&xmu, &xvar, &y, 0.0, 2);
    let mut rng = Rng::new(seed + 1);
    let params = GlobalParams {
        z: Matrix::from_fn(8, 2, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let cfg = TrainConfig {
        artifact: "test".into(),
        artifacts_dir: artifacts_dir(),
        workers: 2,
        model: ModelKind::Regression,
        global_opt: GlobalOpt::Scg,
        seed: 1,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg, params, shards).unwrap();
    t.train(iters).unwrap();
    t.export_model().unwrap()
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: diverged at {i}: {x} vs {y}");
    }
}

/// Keep a spawned fleet member from outliving a failed test.
struct Proc(Child);

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Spawn `gparml <args>` and block until it announces `listening on
/// ADDR` on stdout (every fleet command binds `--listen 127.0.0.1:0`
/// and prints the resolved address in its banner).
fn spawn_gparml(args: &[&str]) -> (Proc, String) {
    let bin = env!("CARGO_BIN_EXE_gparml");
    let mut child = Command::new(bin)
        .args(args)
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawning gparml fleet process");
    let stdout = child.stdout.take().expect("child stdout is piped");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("reading child stdout");
        assert!(n > 0, "gparml {args:?} exited before announcing its address");
        if let Some((_, rest)) = line.split_once("listening on ") {
            break rest
                .split_whitespace()
                .next()
                .expect("an address follows the banner")
                .to_string();
        }
    };
    // keep draining so the child never blocks on a full stdout pipe
    std::thread::spawn(move || {
        let mut sink = String::new();
        while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
            sink.clear();
        }
    });
    (Proc(child), addr)
}

fn wait_until(what: &str, timeout: Duration, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Pull one numeric metric out of a `ServeStats` JSON snapshot.
fn metric(snapshot: &str, section: &str, name: &str) -> f64 {
    let json = Json::parse(snapshot).expect("stats snapshot is JSON");
    json.get(section)
        .and_then(|s| s.get(name))
        .unwrap_or_else(|| panic!("snapshot missing {section}/{name}"))
        .as_f64()
        .unwrap()
}

/// The tentpole acceptance, end to end over real processes: register,
/// route, fail over, roll, evict.
#[test]
fn fleet_predicts_fails_over_and_rolls_reloads_through_the_front_door() {
    let model_a = trained_model(211, 2);
    let model_b = trained_model(223, 4);
    let mut rng = Rng::new(29);
    let xt_mu = Matrix::from_fn(24, 2, |_, _| rng.range(-2.0, 2.0));
    let xt_var = Matrix::from_fn(24, 2, |_, _| 0.05 * rng.uniform());
    let local_a = Predictor::new(&model_a).unwrap().predict(&xt_mu, &xt_var).unwrap();
    let local_b = Predictor::new(&model_b).unwrap().predict(&xt_mu, &xt_var).unwrap();

    let path = tmp_path("fleet.gpm");
    model_a.save(&path).unwrap();
    let model_arg = path.to_str().unwrap();

    let (_control, control_addr) = spawn_gparml(&[
        "control",
        "--listen",
        "127.0.0.1:0",
        "--stale-ms",
        "2000",
        "--sweep-ms",
        "100",
    ]);
    let spawn_replica = || {
        spawn_gparml(&[
            "serve",
            "--model",
            model_arg,
            "--listen",
            "127.0.0.1:0",
            "--control",
            &control_addr,
            "--heartbeat-ms",
            "100",
        ])
    };
    let (mut replica_a, addr_a) = spawn_replica();
    let (_replica_b, addr_b) = spawn_replica();
    // NOTE the slow membership refresh: a SIGKILLed replica drops its
    // control connection, which deregisters it instantly, and a
    // too-eager lb poll could then remove the corpse from the pool
    // before the predict loop below ever routes to it — the 1s cadence
    // keeps the failover path deterministically exercised while the
    // loop runs.
    let (_lb, lb_addr) = spawn_gparml(&[
        "lb",
        "--listen",
        "127.0.0.1:0",
        "--connect",
        &control_addr,
        "--interval-ms",
        "1000",
    ]);

    // both replicas register with the control plane under their bound
    // addresses, and the lb's pool follows
    let mut ctl = ControlClient::connect(&control_addr).unwrap();
    wait_until("both replicas to register", Duration::from_secs(30), || {
        ctl.fleet_info().unwrap().len() == 2
    });
    let fleet: Vec<String> = ctl.fleet_info().unwrap().into_iter().map(|r| r.addr).collect();
    assert!(
        fleet.contains(&addr_a) && fleet.contains(&addr_b),
        "fleet advertises {fleet:?}, expected {addr_a} and {addr_b}"
    );
    let mut stats_client = ServeClient::connect(&lb_addr).unwrap();
    wait_until(
        "the lb to see two healthy backends",
        Duration::from_secs(30),
        || metric(&stats_client.stats().unwrap(), "gauges", "lb.healthy") >= 2.0,
    );

    // predict through the front door: a NO-retry client, so any
    // lb-side slip is a hard failure here, not a masked retry
    let mut client =
        ServeClient::with_opts(&lb_addr, serve::ConnectOpts::default().no_retry()).unwrap();
    let info = client.model_info().unwrap();
    assert_eq!((info.m, info.q, info.d), (8, 2, 3));
    assert_eq!(info.version, 1, "fresh replicas must serve model version 1");
    let (mean, var) = client.predict(&xt_mu, &xt_var).unwrap();
    assert_bits_eq(local_a.0.data(), mean.data(), "lb predict mean (model A)");
    assert_bits_eq(&local_a.1, &var, "lb predict var (model A)");

    // a direct replica answer is the same bytes — the front door adds
    // routing, never arithmetic
    let mut direct = ServeClient::connect(&addr_a).unwrap();
    let (mean_d, var_d) = direct.predict(&xt_mu, &xt_var).unwrap();
    assert_bits_eq(mean.data(), mean_d.data(), "direct vs lb mean");
    assert_bits_eq(&var, &var_d, "direct vs lb var");
    direct.hangup();

    // one reload at the front door rolls the WHOLE fleet onto the new
    // artifact bytes
    model_b.save(&path).unwrap();
    let info = client.reload().unwrap();
    assert_eq!(info.version, 2, "rolling reload must land the fleet on version 2");
    for addr in [&addr_a, &addr_b] {
        let mut direct = ServeClient::connect(addr).unwrap();
        assert_eq!(
            direct.model_info().unwrap().version,
            2,
            "replica {addr} did not reload"
        );
        direct.hangup();
    }
    let (mean, var) = client.predict(&xt_mu, &xt_var).unwrap();
    assert_bits_eq(local_b.0.data(), mean.data(), "lb predict mean (model B)");
    assert_bits_eq(&local_b.1, &var, "lb predict var (model B)");
    wait_until(
        "version convergence to surface at the front door",
        Duration::from_secs(10),
        || {
            let snapshot = stats_client.stats().unwrap();
            metric(&snapshot, "counters", "lb.reloads") >= 2.0
                && metric(&snapshot, "gauges", "lb.version_skew") == 0.0
        },
    );

    // SIGKILL one replica mid-stream: the lb retries the failed
    // request once on the sibling, so the no-retry client never sees
    // an error and every answer stays bit-identical
    for i in 0..30 {
        if i == 5 {
            replica_a.0.kill().expect("kill replica");
            replica_a.0.wait().expect("reap replica");
        }
        let (mean, var) = client.predict(&xt_mu, &xt_var).unwrap();
        assert_bits_eq(local_b.0.data(), mean.data(), "predict mean across the kill");
        assert_bits_eq(&local_b.1, &var, "predict var across the kill");
    }
    assert!(
        metric(&stats_client.stats().unwrap(), "counters", "lb.failovers") >= 1.0,
        "the kill never exercised the failover path"
    );

    // the kill dropped the replica's control connection, which is an
    // implicit deregister (heartbeat staleness covers wedged-but-
    // connected replicas); its last beat advertised the reloaded
    // version, and the front door follows the shrunken fleet
    wait_until(
        "the control plane to evict the killed replica",
        Duration::from_secs(10),
        || {
            let fleet = ctl.fleet_info().unwrap();
            fleet.len() == 1 && fleet[0].addr == addr_b && fleet[0].model_version == 2
        },
    );
    wait_until(
        "the lb to drop the dead backend",
        Duration::from_secs(10),
        || metric(&stats_client.stats().unwrap(), "gauges", "lb.healthy") == 1.0,
    );

    client.hangup();
    stats_client.hangup();
    std::fs::remove_file(&path).ok();
}

/// In-process front door smoke: a static single-replica lb routes the
/// standard serve verbs bit-exactly, answers its own `ServeStats`
/// inline, and the whole stack (replica accept loop + lb accept loop
/// + health refresher) winds down cleanly by client counting alone —
/// no kills, no sleeps.
#[test]
fn static_lb_routes_bitwise_counts_and_drains_cleanly() {
    let model = trained_model(241, 3);
    let pred = Predictor::new(&model).unwrap();
    let mut rng = Rng::new(31);
    let xt_mu = Matrix::from_fn(17, 2, |_, _| rng.range(-2.0, 2.0));
    let xt_var = Matrix::from_fn(17, 2, |_, _| 0.05 * rng.uniform());
    let (mean_l, var_l) = pred.predict(&xt_mu, &xt_var).unwrap();

    let state = ServeState::new(pred);
    // replica budget: the lb holds one backend link for our client's
    // connection plus one cached health-probe connection
    let replica_opts = ServeOptions {
        max_clients: 2,
        ..Default::default()
    };
    let replica_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let replica_addr = replica_listener.local_addr().unwrap().to_string();
    let lb_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let lb_addr = lb_listener.local_addr().unwrap().to_string();
    let lb_opts = LbOptions {
        max_clients: 1,
        refresh_ms: 50,
        ..Default::default()
    };
    let upstream = Upstream::Static(vec![replica_addr.clone()]);

    const REPS: usize = 6;
    let (serve_stats, lb_stats) = std::thread::scope(|s| {
        let replica = s.spawn(|| serve::serve(&replica_listener, &state, &replica_opts).unwrap());
        let front = s.spawn(|| run_lb(&lb_listener, &upstream, &lb_opts).unwrap());

        let mut client =
            ServeClient::with_opts(&lb_addr, serve::ConnectOpts::default().no_retry()).unwrap();
        assert_eq!(client.model_info().unwrap().version, 1);
        for _ in 0..REPS {
            let (mean, var) = client.predict(&xt_mu, &xt_var).unwrap();
            assert_bits_eq(mean_l.data(), mean.data(), "static lb mean");
            assert_bits_eq(&var_l, &var, "static lb var");
        }
        let snapshot = client.stats().unwrap();
        assert_eq!(
            metric(&snapshot, "counters", "lb.requests.predict"),
            REPS as f64
        );
        assert_eq!(metric(&snapshot, "counters", "lb.requests.model_info"), 1.0);
        client.hangup();
        (replica.join().unwrap(), front.join().unwrap())
    });
    assert_eq!(lb_stats.clients, 1);
    assert_eq!(
        lb_stats.failovers, 0,
        "a healthy static pool must never fail over"
    );
    assert_eq!(
        serve_stats.clients, 2,
        "the replica should count exactly the backend link and the probe"
    );
    assert!(
        serve_stats.requests >= (REPS + 1) as u64,
        "the forwarded verbs never reached the replica"
    );
}
