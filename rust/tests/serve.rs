//! End-to-end tests of the serving subsystem (DESIGN.md §9): micro-
//! batched replies bit-identical to local prediction under concurrent
//! clients, misbehaving clients neither killing the server nor
//! consuming `--clients` slots, atomic model hot-reload with version
//! detection, LVM latent-projection serving, and the `--iters 0`
//! resume/re-export CLI path printing a NaN-free summary.

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::Command;

use gparml::cluster::wire::{self, Frame, Request};
use gparml::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use gparml::gp::GlobalParams;
use gparml::linalg::Matrix;
use gparml::model::{serve, Predictor, ServeClient, ServeOptions, ServeState, TrainedModel};
use gparml::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gparml_serve_{}_{name}", std::process::id()))
}

fn regression_data(n: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let xmu = Matrix::from_fn(n, 2, |_, _| rng.range(-2.0, 2.0));
    let xvar = Matrix::zeros(n, 2);
    let y = Matrix::from_fn(n, 3, |i, j| {
        let x = xmu[(i, 0)];
        let f = match j {
            0 => x.sin(),
            1 => (1.3 * x).cos(),
            _ => 0.5 * x,
        };
        f + 0.05 * rng.normal()
    });
    (xmu, xvar, y)
}

/// Train a tiny regression cluster and export its model.
fn trained_model(seed: u64, iters: usize) -> TrainedModel {
    let (xmu, xvar, y) = regression_data(60, seed);
    let shards = partition(&xmu, &xvar, &y, 0.0, 2);
    let mut rng = Rng::new(seed + 1);
    let params = GlobalParams {
        z: Matrix::from_fn(8, 2, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let cfg = TrainConfig {
        artifact: "test".into(),
        artifacts_dir: artifacts_dir(),
        workers: 2,
        model: ModelKind::Regression,
        global_opt: GlobalOpt::Scg,
        seed: 1,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg, params, shards).unwrap();
    t.train(iters).unwrap();
    t.export_model().unwrap()
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: diverged at {i}: {x} vs {y}");
    }
}

/// The tentpole acceptance: ≥4 concurrent clients hammer one server
/// whose single worker coalesces queued requests across clients;
/// every reply is bit-identical to a local `Predictor::predict` of
/// the same (per-client, differently-sized) batch — micro-batching
/// changes throughput, never bytes. A "heavy" client sends a large
/// first batch and only then releases the small clients, so while the
/// single worker chews on it (or before its first queue pop) the small
/// clients' requests pile up and MUST coalesce — the split-reply path
/// is exercised deterministically, not by scheduler luck.
#[test]
fn micro_batched_replies_are_bitwise_under_six_concurrent_clients() {
    let model = trained_model(101, 3);
    let pred = Predictor::new(&model).unwrap();

    const SMALL_CLIENTS: usize = 5; // + 1 heavy = 6 concurrent
    const REPS: usize = 12;
    let mut rng = Rng::new(199);
    let heavy_mu = Matrix::from_fn(4000, 2, |_, _| rng.range(-2.0, 2.0));
    let heavy_var = Matrix::from_fn(4000, 2, |_, _| 0.05 * rng.uniform());
    let heavy_local = pred.predict(&heavy_mu, &heavy_var).unwrap();
    // per-client batches of different sizes: the reply-splitting path
    // has to get every row window right
    let batches: Vec<(Matrix, Matrix)> = (0..SMALL_CLIENTS)
        .map(|c| {
            let mut rng = Rng::new(200 + c as u64);
            let t = 40 + 37 * c;
            let xt_mu = Matrix::from_fn(t, 2, |_, _| rng.range(-2.0, 2.0));
            let xt_var = Matrix::from_fn(t, 2, |_, _| 0.05 * rng.uniform());
            (xt_mu, xt_var)
        })
        .collect();
    let locals: Vec<(Matrix, Vec<f64>)> = batches
        .iter()
        .map(|(mu, var)| pred.predict(mu, var).unwrap())
        .collect();

    let state = ServeState::new(pred);
    let opts = ServeOptions {
        max_clients: (SMALL_CLIENTS + 1) as u64,
        workers: 1, // one worker + 6 synchronous clients => queues build
        max_batch_rows: 8192,
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let stats = std::thread::scope(|s| {
        let server = s.spawn(|| serve::serve(&listener, &state, &opts).unwrap());
        let (sent_tx, sent_rx) = std::sync::mpsc::channel::<()>();

        let heavy = s.spawn(|| {
            // raw frames on a raw socket: this client needs to split
            // the write from the read, which the typed ServeClient
            // (request = write + read) deliberately does not expose
            let mut stream = TcpStream::connect(addr.as_str()).unwrap();
            stream.set_nodelay(true).ok();
            // put the big request on the wire, THEN release the small
            // clients: their requests land while the worker is busy
            wire::write_frame(
                &mut stream,
                &Frame::Request {
                    trace_id: 0x8EA7_1D,
                    req: Box::new(Request::ServePredict {
                        xt_mu: heavy_mu.clone(),
                        xt_var: heavy_var.clone(),
                    }),
                },
            )
            .unwrap();
            sent_tx.send(()).unwrap();
            let (mean_r, var_r) = match wire::read_frame(&mut stream).unwrap() {
                Some((Frame::Response { resp, .. }, _)) => match *resp {
                    wire::Response::Predict { mean, var } => (mean, var),
                    other => panic!("unexpected heavy reply {other:?}"),
                },
                other => panic!("unexpected heavy frame {other:?}"),
            };
            assert_bits_eq(heavy_local.0.data(), mean_r.data(), "heavy mean");
            assert_bits_eq(&heavy_local.1, &var_r, "heavy var");
            wire::write_frame(&mut stream, &Frame::Shutdown).unwrap();
        });

        sent_rx.recv().unwrap();
        let clients: Vec<_> = (0..SMALL_CLIENTS)
            .map(|c| {
                let addr = &addr;
                let (xt_mu, xt_var) = &batches[c];
                let (mean_l, var_l) = &locals[c];
                s.spawn(move || {
                    let mut client = ServeClient::connect(addr).unwrap();
                    for rep in 0..REPS {
                        let (mean_r, var_r) = client.predict(xt_mu, xt_var).unwrap();
                        assert_bits_eq(
                            mean_l.data(),
                            mean_r.data(),
                            &format!("client {c} rep {rep} mean"),
                        );
                        assert_bits_eq(var_l, &var_r, &format!("client {c} rep {rep} var"));
                    }
                    client.hangup();
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }
        heavy.join().unwrap();
        server.join().unwrap()
    });

    assert_eq!(stats.clients, (SMALL_CLIENTS + 1) as u64);
    assert_eq!(stats.requests, (SMALL_CLIENTS * REPS + 1) as u64);
    // the small clients' requests queued behind the heavy one: strictly
    // fewer kernel calls than requests, and some jobs shared a call
    assert!(
        stats.batches < stats.requests,
        "no micro-batching happened: {} kernel calls for {} requests",
        stats.batches,
        stats.requests
    );
    assert!(stats.coalesced_jobs > 0, "no jobs were ever coalesced");
}

/// Churn: clients that hang up instantly, speak garbage, or die
/// mid-frame must neither kill the server nor count toward
/// `--clients`; a client that dies after a valid frame counts but
/// still cannot stall anyone else.
#[test]
fn misbehaving_clients_neither_kill_the_server_nor_consume_slots() {
    let model = trained_model(111, 2);
    let pred = Predictor::new(&model).unwrap();
    let mut rng = Rng::new(7);
    let xt_mu = Matrix::from_fn(9, 2, |_, _| rng.range(-2.0, 2.0));
    let xt_var = Matrix::zeros(9, 2);
    let (mean_l, var_l) = pred.predict(&xt_mu, &xt_var).unwrap();

    let state = ServeState::new(pred);
    let opts = ServeOptions {
        max_clients: 2, // the valid-frame client below + the good client
        workers: 1,
        max_batch_rows: 4096,
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let stats = std::thread::scope(|s| {
        let server = s.spawn(|| serve::serve(&listener, &state, &opts).unwrap());

        // (a) connect + instant hangup: no frame, no slot
        drop(TcpStream::connect(&addr).unwrap());
        // (b) garbage bytes (wrong magic): decode error, no slot
        let mut garbage = TcpStream::connect(&addr).unwrap();
        garbage.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        drop(garbage);
        // (c) death mid-frame: half a valid request, then gone — a
        // truncated frame, no slot
        let frame = wire::encode_frame(&Frame::Request {
            trace_id: 7,
            req: Box::new(Request::ServePredict {
                xt_mu: xt_mu.clone(),
                xt_var: xt_var.clone(),
            }),
        })
        .unwrap();
        let mut half = TcpStream::connect(&addr).unwrap();
        half.write_all(&frame[..frame.len() / 2]).unwrap();
        drop(half);
        // (d) death mid-request AFTER a complete valid frame: counts
        // as a client (it completed one), reply hits a dead socket
        let mut dier = TcpStream::connect(&addr).unwrap();
        dier.write_all(&frame).unwrap();
        drop(dier);

        // the good client is served correctly through all of the above
        let mut client = ServeClient::connect(&addr).unwrap();
        let info = client.model_info().unwrap();
        assert_eq!((info.m, info.q, info.d), (8, 2, 3));
        // (e) a decodable but malformed request — xt_mu/xt_var shapes
        // disagree — draws an error reply, not a dead worker (it must
        // never reach the batch concatenation). A semantic error keeps
        // the connection: the next predict reuses it (one counted
        // client), which this test's max_clients=2 budget relies on.
        let err = format!(
            "{:#}",
            client.predict(&xt_mu, &Matrix::zeros(3, 2)).unwrap_err()
        );
        assert!(err.contains("disagree"), "{err}");
        assert!(client.is_connected(), "semantic error must not drop the connection");
        let (mean_r, var_r) = client.predict(&xt_mu, &xt_var).unwrap();
        assert_bits_eq(mean_l.data(), mean_r.data(), "post-churn mean");
        assert_bits_eq(&var_l, &var_r, "post-churn var");
        client.hangup();

        server.join().unwrap()
    });

    // exactly the frame-completing connections counted: the
    // mid-request casualty (d) and the good client — never (a)-(c)
    assert_eq!(
        stats.clients, 2,
        "instant-hangup/garbage/truncated clients must not consume slots"
    );
}

/// Hot reload: the artifact file is replaced on disk, a `Reload`
/// frame swaps it in atomically, the model version bumps, and
/// predictions switch to the new model bit-exactly. A failed reload
/// (corrupt file) keeps the old model serving.
#[test]
fn hot_reload_swaps_model_bumps_version_and_survives_corrupt_files() {
    let model_a = trained_model(121, 2);
    let model_b = trained_model(131, 4);
    let mut rng = Rng::new(17);
    let xt_mu = Matrix::from_fn(7, 2, |_, _| rng.range(-2.0, 2.0));
    let xt_var = Matrix::zeros(7, 2);
    let (mean_a, var_a) = Predictor::new(&model_a).unwrap().predict(&xt_mu, &xt_var).unwrap();
    let (mean_b, var_b) = Predictor::new(&model_b).unwrap().predict(&xt_mu, &xt_var).unwrap();
    assert!(
        mean_a.max_abs_diff(&mean_b) > 0.0,
        "the two models agree — the reload test lost its teeth"
    );

    let path = tmp_path("reload.gpm");
    model_a.save(&path).unwrap();
    let state = ServeState::with_path(Predictor::new(&model_a).unwrap(), path.clone());
    let opts = ServeOptions {
        max_clients: 1,
        ..Default::default()
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let stats = std::thread::scope(|s| {
        let server = s.spawn(|| serve::serve(&listener, &state, &opts).unwrap());
        let mut client = ServeClient::connect(&addr).unwrap();

        let info = client.model_info().unwrap();
        assert_eq!(info.version, 1);
        let (mean_r, var_r) = client.predict(&xt_mu, &xt_var).unwrap();
        assert_bits_eq(mean_a.data(), mean_r.data(), "pre-reload mean");
        assert_bits_eq(&var_a, &var_r, "pre-reload var");

        // swap the artifact on disk, then ask the server to reload
        model_b.save(&path).unwrap();
        let info = client.reload().unwrap();
        assert_eq!(info.version, 2, "reload must bump the model version");
        let (mean_r, var_r) = client.predict(&xt_mu, &xt_var).unwrap();
        assert_bits_eq(mean_b.data(), mean_r.data(), "post-reload mean");
        assert_bits_eq(&var_b, &var_r, "post-reload var");

        // a corrupt artifact must fail the reload and keep serving B
        std::fs::write(&path, b"not a model").unwrap();
        let err = format!("{:#}", client.reload().unwrap_err());
        assert!(err.contains("reload failed"), "{err}");
        let info = client.model_info().unwrap();
        assert_eq!(info.version, 2, "failed reload must not swap or bump");
        let (mean_r, _) = client.predict(&xt_mu, &xt_var).unwrap();
        assert_bits_eq(mean_b.data(), mean_r.data(), "post-failed-reload mean");

        client.hangup();
        server.join().unwrap()
    });
    std::fs::remove_file(&path).ok();
    assert_eq!(stats.clients, 1);
}

/// LVM latent-projection serving: concurrent `ServeProject` and
/// `ServePredict` clients share the queue (kind-grouped batching) and
/// every projection is bit-identical to the local `Predictor::project`.
#[test]
fn serve_project_is_bitwise_alongside_predict_clients() {
    let model = trained_model(141, 3);
    let pred = Predictor::new(&model).unwrap();
    let mut rng = Rng::new(27);
    let y = Matrix::from_fn(13, 3, |_, _| rng.normal());
    let xt_mu = Matrix::from_fn(6, 2, |_, _| rng.range(-2.0, 2.0));
    let xt_var = Matrix::zeros(6, 2);
    let (xmu_l, conf_l) = pred.project(&y).unwrap();
    let (mean_l, var_l) = pred.predict(&xt_mu, &xt_var).unwrap();
    assert_eq!((xmu_l.rows(), xmu_l.cols()), (13, 2));

    let state = ServeState::new(pred);
    let opts = ServeOptions {
        max_clients: 4,
        workers: 1,
        max_batch_rows: 4096,
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let stats = std::thread::scope(|s| {
        let server = s.spawn(|| serve::serve(&listener, &state, &opts).unwrap());
        let mut handles = Vec::new();
        for _ in 0..2 {
            let (addr, y, xmu_l, conf_l) = (&addr, &y, &xmu_l, &conf_l);
            handles.push(s.spawn(move || {
                let mut client = ServeClient::connect(addr).unwrap();
                for _ in 0..8 {
                    let (xmu_r, conf_r) = client.project(y).unwrap();
                    assert_bits_eq(xmu_l.data(), xmu_r.data(), "remote projection");
                    assert_bits_eq(conf_l, &conf_r, "remote projection conf");
                }
                client.hangup();
            }));
        }
        for _ in 0..2 {
            let (addr, xt_mu, xt_var, mean_l, var_l) = (&addr, &xt_mu, &xt_var, &mean_l, &var_l);
            handles.push(s.spawn(move || {
                let mut client = ServeClient::connect(addr).unwrap();
                for _ in 0..8 {
                    let (mean_r, var_r) = client.predict(xt_mu, xt_var).unwrap();
                    assert_bits_eq(mean_l.data(), mean_r.data(), "interleaved predict mean");
                    assert_bits_eq(var_l, &var_r, "interleaved predict var");
                }
                client.hangup();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        server.join().unwrap()
    });
    assert_eq!(stats.clients, 4);
    assert_eq!(stats.requests, 32);
}

/// Satellite: the `--iters 0` `--resume` + `--export` re-export CLI
/// path works end-to-end, prints a NaN-free summary, and re-exports a
/// model that predicts byte-identically to the original export.
#[test]
fn iters_zero_resume_reexport_is_nan_free_and_byte_identical() {
    let bin = env!("CARGO_BIN_EXE_gparml");
    let art = artifacts_dir();
    let ck = tmp_path("reexport.gpc");
    let m1 = tmp_path("reexport_m1.gpm");
    let m2 = tmp_path("reexport_m2.gpm");
    let p1 = tmp_path("reexport_p1.csv");
    let p2 = tmp_path("reexport_p2.csv");

    let run = |extra: &[&str]| {
        let out = Command::new(bin)
            .args([
                "train",
                "--data",
                "synthetic",
                "--model",
                "reg",
                "--n",
                "240",
                "--workers",
                "2",
                "--seed",
                "5",
                "--artifacts",
                art.to_str().unwrap(),
            ])
            .args(extra)
            .output()
            .expect("spawning gparml train");
        assert!(
            out.status.success(),
            "train failed:\n{}\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8_lossy(&out.stdout).to_string()
    };

    run(&[
        "--iters",
        "2",
        "--checkpoint",
        ck.to_str().unwrap(),
        "--export",
        m1.to_str().unwrap(),
    ]);
    // the satellite case: resume, run zero iterations, re-export
    let stdout = run(&[
        "--iters",
        "0",
        "--resume",
        ck.to_str().unwrap(),
        "--export",
        m2.to_str().unwrap(),
    ]);
    assert!(
        !stdout.contains("NaN"),
        "0-iteration summary printed NaN:\n{stdout}"
    );
    assert!(
        stdout.contains("no iterations run"),
        "missing the guarded summary line:\n{stdout}"
    );

    // both exports predict byte-identically through the CLI
    let predict = |model: &PathBuf, out_csv: &PathBuf| {
        let out = Command::new(bin)
            .args([
                "predict",
                "--model",
                model.to_str().unwrap(),
                "--n",
                "32",
                "--seed",
                "9",
                "--out",
                out_csv.to_str().unwrap(),
            ])
            .output()
            .expect("spawning gparml predict");
        assert!(
            out.status.success(),
            "predict failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
    };
    predict(&m1, &p1);
    predict(&m2, &p2);
    let b1 = std::fs::read(&p1).unwrap();
    let b2 = std::fs::read(&p2).unwrap();
    assert_eq!(b1, b2, "re-exported model predicts differently");

    // stronger: with resume provenance carried through (iterations,
    // final bound), the re-exported artifact is byte-identical
    let a1 = std::fs::read(&m1).unwrap();
    let a2 = std::fs::read(&m2).unwrap();
    assert_eq!(a1, a2, "re-exported artifact bytes differ from the original export");

    for f in [&ck, &m1, &m2, &p1, &p2] {
        std::fs::remove_file(f).ok();
    }
}
