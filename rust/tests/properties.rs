//! Property-based tests over the native substrates (randomised invariant
//! checks via `gparml::testing`; proptest is unavailable offline —
//! DESIGN.md §5). Every property prints the failing seed on violation.

use std::collections::BTreeMap;

use gparml::coordinator::partition;
use gparml::gp::{self, kernel, GlobalParams, Stats};
use gparml::linalg::{Cholesky, Matrix};
use gparml::optim::Scg;
use gparml::runtime::{build_executor, ArtifactConfig, ShardData};
use gparml::testing::{check, close, dim, mat_close, random_matrix, random_spd};
use gparml::util::json::Json;
use gparml::util::rng::Rng;

fn random_params(rng: &mut Rng, m: usize, q: usize) -> GlobalParams {
    GlobalParams {
        z: random_matrix(rng, m, q, 1.0),
        log_ls: (0..q).map(|_| 0.3 * rng.normal()).collect(),
        log_sf2: 0.2 * rng.normal(),
        log_beta: 1.0 + 0.3 * rng.normal(),
    }
}

// ---------------------------------------------------------------------------
// linalg
// ---------------------------------------------------------------------------

#[test]
fn prop_cholesky_roundtrip() {
    check("cholesky reconstructs A", 40, |rng| {
        let n = dim(rng, 1, 10);
        let a = random_spd(rng, n, 0.3);
        let ch = Cholesky::new(&a).map_err(|e| e.to_string())?;
        mat_close(&ch.l().matmul_t(ch.l()), &a, 1e-10, "L L^T")
    });
}

#[test]
fn prop_solve_inverts() {
    check("A * solve(A, b) == b", 40, |rng| {
        let n = dim(rng, 1, 9);
        let a = random_spd(rng, n, 0.4);
        let cols = dim(rng, 1, 4);
        let b = random_matrix(rng, n, cols, 1.0);
        let ch = Cholesky::new(&a).map_err(|e| e.to_string())?;
        mat_close(&a.matmul(&ch.solve(&b)), &b, 1e-9, "Ax = b")
    });
}

#[test]
fn prop_logdet_scaling() {
    check("log|cA| = n log c + log|A|", 30, |rng| {
        let n = dim(rng, 2, 8);
        let a = random_spd(rng, n, 0.5);
        let c = 0.5 + rng.uniform() * 2.0;
        let ld_a = Cholesky::new(&a).unwrap().log_det();
        let ld_ca = Cholesky::new(&a.scale(c)).unwrap().log_det();
        close(ld_ca, n as f64 * c.ln() + ld_a, 1e-10, "logdet scaling")
    });
}

#[test]
fn prop_matmul_associative() {
    check("(AB)C == A(BC)", 30, |rng| {
        let (a, b, c, d) = (dim(rng, 1, 6), dim(rng, 1, 6), dim(rng, 1, 6), dim(rng, 1, 6));
        let x = random_matrix(rng, a, b, 1.0);
        let y = random_matrix(rng, b, c, 1.0);
        let z = random_matrix(rng, c, d, 1.0);
        mat_close(
            &x.matmul(&y).matmul(&z),
            &x.matmul(&y.matmul(&z)),
            1e-11,
            "associativity",
        )
    });
}

// ---------------------------------------------------------------------------
// kernel statistics
// ---------------------------------------------------------------------------

#[test]
fn prop_stats_additive_under_any_partition() {
    check("stats additive over random partition", 20, |rng| {
        let (m, q, d) = (dim(rng, 2, 6), dim(rng, 1, 3), dim(rng, 1, 4));
        let n = dim(rng, 4, 24);
        let p = random_params(rng, m, q);
        let xmu = random_matrix(rng, n, q, 1.0);
        let xvar = Matrix::from_fn(n, q, |_, _| 0.05 + rng.uniform());
        let y = random_matrix(rng, n, d, 1.0);
        let whole = kernel::shard_stats(&p, &xmu, &xvar, &y, &vec![1.0; n], 1.0);
        // random split point
        let k = 1 + rng.below(n - 1);
        let shards = partition(&xmu, &xvar, &y, 1.0, 1 + k.min(4));
        let mut acc = Stats::zeros(m, d);
        for s in &shards {
            acc.accumulate(&kernel::shard_stats(
                &p, &s.xmu, &s.xvar, &s.y, &vec![1.0; s.len()], 1.0,
            ));
        }
        close(acc.a, whole.a, 1e-11, "a")?;
        close(acc.psi0, whole.psi0, 1e-11, "psi0")?;
        close(acc.kl, whole.kl, 1e-11, "kl")?;
        mat_close(&acc.c, &whole.c, 1e-11, "C")?;
        mat_close(&acc.d, &whole.d, 1e-11, "D")
    });
}

#[test]
fn prop_psi2_symmetric_psd() {
    check("Psi2 symmetric and PSD", 25, |rng| {
        let (m, q) = (dim(rng, 2, 7), dim(rng, 1, 3));
        let n = dim(rng, 3, 15);
        let p = random_params(rng, m, q);
        let xmu = random_matrix(rng, n, q, 1.0);
        let xvar = Matrix::from_fn(n, q, |_, _| 0.05 + rng.uniform());
        let y = random_matrix(rng, n, 2, 1.0);
        let st = kernel::shard_stats(&p, &xmu, &xvar, &y, &vec![1.0; n], 1.0);
        mat_close(&st.d, &st.d.transpose(), 1e-11, "symmetry")?;
        // PSD: Psi2 = sum_i E[k k^T] is a sum of PSD expectations
        Cholesky::new(&st.d.add_diag(1e-9))
            .map(|_| ())
            .map_err(|e| format!("not PSD: {e}"))
    });
}

// ---------------------------------------------------------------------------
// math modes: Fast vs Strict numerical contract (DESIGN.md §8)
// ---------------------------------------------------------------------------

#[test]
fn prop_fast_stats_match_strict_within_1e9() {
    check("fast shard stats within 1e-9 of strict", 20, |rng| {
        let (m, q, d) = (dim(rng, 2, 7), dim(rng, 1, 4), dim(rng, 1, 4));
        let n = dim(rng, 2, 22);
        let p = random_params(rng, m, q);
        let xmu = random_matrix(rng, n, q, 1.0);
        let xvar = Matrix::from_fn(n, q, |_, _| 0.05 + rng.uniform());
        let y = random_matrix(rng, n, d, 1.0);
        let mask = vec![1.0; n];
        let strict = kernel::shard_stats(&p, &xmu, &xvar, &y, &mask, 1.0);
        let mut scratch = kernel::ShardScratch::new();
        let fast = kernel::shard_stats_into_fast(&p, &xmu, &xvar, &y, &mask, 1.0, &mut scratch);
        close(fast.a, strict.a, 1e-12, "a")?;
        close(fast.psi0, strict.psi0, 1e-12, "psi0")?;
        close(fast.kl, strict.kl, 1e-12, "kl")?;
        mat_close(&fast.c, &strict.c, 1e-9, "C fast vs strict")?;
        mat_close(&fast.d, &strict.d, 1e-9, "D fast vs strict")
    });
}

#[test]
fn prop_fast_bound_and_grads_match_strict_within_1e9() {
    check("fast bound/gradients within 1e-9 of strict", 15, |rng| {
        let (m, q, d) = (dim(rng, 2, 6), dim(rng, 1, 3), dim(rng, 1, 3));
        let n = dim(rng, 3, 18);
        // the trainer's default jitter: keeps Kmm's conditioning from
        // amplifying the kernels' ulp-level drift through the solves
        let jitter = 1e-6;
        let p = random_params(rng, m, q);
        let xmu = random_matrix(rng, n, q, 1.0);
        let xvar = Matrix::from_fn(n, q, |_, _| 0.05 + rng.uniform());
        let y = random_matrix(rng, n, d, 1.0);
        let mask = vec![1.0; n];
        let kmm = kernel::kmm(&p, jitter);

        // strict pipeline: reference stats -> bound -> adjoints -> VJP
        let st_s = kernel::shard_stats(&p, &xmu, &xvar, &y, &mask, 1.0);
        let (bv_s, adj_s) = gp::assemble_bound(&st_s, &kmm, p.log_beta, d).unwrap();
        let (g_s, dmu_s, dvar_s) = kernel::shard_grads_vjp(&p, &xmu, &xvar, &y, 1.0, &adj_s);

        // fast pipeline under the SAME adjoint message: isolates the
        // kernel-arithmetic contract (the central reduce is identical
        // code in both modes, so the adjoints a Fast cluster sees can
        // only differ through the stats, checked separately above)
        let mut scratch = kernel::ShardScratch::new();
        let st_f = kernel::shard_stats_into_fast(&p, &xmu, &xvar, &y, &mask, 1.0, &mut scratch);
        let (bv_f, _) = gp::assemble_bound(&st_f, &kmm, p.log_beta, d).unwrap();
        let (g_f, dmu_f, dvar_f) =
            kernel::shard_grads_vjp_cached_fast(&p, &xmu, &xvar, &y, 1.0, &adj_s, &mut scratch);

        close(bv_f.f, bv_s.f, 1e-9, "bound F fast vs strict")?;
        mat_close(&g_f.d_z, &g_s.d_z, 1e-9, "dZ fast vs strict")?;
        close(g_f.d_log_sf2, g_s.d_log_sf2, 1e-9, "dlog_sf2 fast vs strict")?;
        for (k, (a, b)) in g_f.d_log_ls.iter().zip(&g_s.d_log_ls).enumerate() {
            close(*a, *b, 1e-9, &format!("dlog_ls[{k}] fast vs strict"))?;
        }
        mat_close(&dmu_f, &dmu_s, 1e-9, "dXmu fast vs strict")?;
        mat_close(&dvar_f, &dvar_s, 1e-9, "dXvar fast vs strict")
    });
}

#[test]
fn prop_fast_threaded_stats_and_grads_match_strict_within_1e9() {
    check("fast threaded fill keeps the 1e-9 contract", 15, |rng| {
        let (m, q, d) = (dim(rng, 2, 6), dim(rng, 1, 3), dim(rng, 1, 3));
        let n = dim(rng, 2, 20);
        let threads = dim(rng, 2, 6);
        let p = random_params(rng, m, q);
        let xmu = random_matrix(rng, n, q, 1.0);
        let xvar = Matrix::from_fn(n, q, |_, _| 0.05 + rng.uniform());
        let y = random_matrix(rng, n, d, 1.0);
        let mask = vec![1.0; n];
        let adj = random_adjoints(rng, m, d);

        // fast pipeline with the psi fill split over a random thread
        // count: the Fast-vs-Strict 1e-9 contract (DESIGN.md §8) must
        // hold unchanged, because threading only re-schedules disjoint
        // writes (DESIGN.md §11)
        let strict = kernel::shard_stats(&p, &xmu, &xvar, &y, &mask, 1.0);
        let mut scratch = kernel::ShardScratch::new();
        scratch.set_fill_threads(threads);
        let fast = kernel::shard_stats_into_fast(&p, &xmu, &xvar, &y, &mask, 1.0, &mut scratch);
        close(fast.a, strict.a, 1e-12, "a")?;
        close(fast.psi0, strict.psi0, 1e-12, "psi0")?;
        close(fast.kl, strict.kl, 1e-12, "kl")?;
        mat_close(&fast.c, &strict.c, 1e-9, "C fast-threaded vs strict")?;
        mat_close(&fast.d, &strict.d, 1e-9, "D fast-threaded vs strict")?;

        let (g_s, dmu_s, dvar_s) = kernel::shard_grads_vjp(&p, &xmu, &xvar, &y, 1.0, &adj);
        let (g_f, dmu_f, dvar_f) =
            kernel::shard_grads_vjp_cached_fast(&p, &xmu, &xvar, &y, 1.0, &adj, &mut scratch);
        mat_close(&g_f.d_z, &g_s.d_z, 1e-9, "dZ fast-threaded vs strict")?;
        close(g_f.d_log_sf2, g_s.d_log_sf2, 1e-9, "dlog_sf2 fast-threaded vs strict")?;
        for (k, (a, b)) in g_f.d_log_ls.iter().zip(&g_s.d_log_ls).enumerate() {
            close(*a, *b, 1e-9, &format!("dlog_ls[{k}] fast-threaded vs strict"))?;
        }
        mat_close(&dmu_f, &dmu_s, 1e-9, "dXmu fast-threaded vs strict")?;
        mat_close(&dvar_f, &dvar_s, 1e-9, "dXvar fast-threaded vs strict")?;

        // and against the SEQUENTIAL fast fill the agreement is exact:
        // the thread count never changes bytes, in either math mode
        let mut seq = kernel::ShardScratch::new();
        let fast1 = kernel::shard_stats_into_fast(&p, &xmu, &xvar, &y, &mask, 1.0, &mut seq);
        bits_f64(fast.a, fast1.a, "a threaded vs sequential fast")?;
        bits_mat(&fast.c, &fast1.c, "C threaded vs sequential fast")?;
        bits_mat(&fast.d, &fast1.d, "D threaded vs sequential fast")
    });
}

#[test]
fn prop_bound_invariant_to_inducing_permutation() {
    check("F invariant under permutation of Z rows", 20, |rng| {
        let (m, q, d) = (dim(rng, 3, 7), dim(rng, 1, 3), dim(rng, 1, 3));
        let n = dim(rng, 5, 20);
        let p = random_params(rng, m, q);
        let xmu = random_matrix(rng, n, q, 1.0);
        let xvar = Matrix::from_fn(n, q, |_, _| 0.05 + rng.uniform());
        let y = random_matrix(rng, n, d, 1.0);
        let f_of = |pp: &GlobalParams| {
            let st = kernel::shard_stats(pp, &xmu, &xvar, &y, &vec![1.0; n], 1.0);
            let kmm = kernel::kmm(pp, 1e-8);
            gp::assemble_bound(&st, &kmm, pp.log_beta, d).unwrap().0.f
        };
        let f1 = f_of(&p);
        // permute inducing points
        let mut order: Vec<usize> = (0..m).collect();
        rng.shuffle(&mut order);
        let p2 = GlobalParams {
            z: Matrix::from_fn(m, q, |i, j| p.z[(order[i], j)]),
            ..p.clone()
        };
        let f2 = f_of(&p2);
        // permuting rows changes the Cholesky elimination order, so exact
        // bit-equality is not expected — only agreement to solver roundoff
        close(f1, f2, 1e-7, "permutation invariance")
    });
}

#[test]
fn prop_collapsed_bound_below_exact_marginal() {
    check("F <= exact log marginal (regression)", 20, |rng| {
        let q = dim(rng, 1, 2);
        let (m, d) = (dim(rng, 2, 6), dim(rng, 1, 3));
        let n = dim(rng, 6, 18);
        let p = random_params(rng, m, q);
        let x = random_matrix(rng, n, q, 1.0);
        let y = random_matrix(rng, n, d, 1.0);
        let st = kernel::shard_stats(&p, &x, &Matrix::zeros(n, q), &y, &vec![1.0; n], 0.0);
        let kmm = kernel::kmm(&p, 1e-10);
        let f = gp::assemble_bound(&st, &kmm, p.log_beta, d).unwrap().0.f;
        let exact = gp::exact::log_marginal(&p, &x, &y).unwrap();
        if f <= exact + 1e-7 {
            Ok(())
        } else {
            Err(format!("bound {f} above exact {exact}"))
        }
    });
}

#[test]
fn prop_adjoints_match_finite_differences() {
    check("adjoint dD/dC match finite differences", 12, |rng| {
        let (m, d) = (dim(rng, 2, 5), dim(rng, 1, 3));
        let n = dim(rng, 5, 15);
        let p = random_params(rng, m, 2);
        let xmu = random_matrix(rng, n, 2, 1.0);
        let xvar = Matrix::from_fn(n, 2, |_, _| 0.05 + rng.uniform());
        let y = random_matrix(rng, n, d, 1.0);
        let st = kernel::shard_stats(&p, &xmu, &xvar, &y, &vec![1.0; n], 1.0);
        let kmm = kernel::kmm(&p, 1e-6);
        let (_, adj) = gp::assemble_bound(&st, &kmm, p.log_beta, d).unwrap();
        let eps = 1e-6;
        let (i, j) = (rng.below(m), rng.below(m));
        let mut sp = st.clone();
        sp.d[(i, j)] += eps;
        let fp = gp::assemble_bound(&sp, &kmm, p.log_beta, d).unwrap().0.f;
        let mut sm = st.clone();
        sm.d[(i, j)] -= eps;
        let fm = gp::assemble_bound(&sm, &kmm, p.log_beta, d).unwrap().0.f;
        close(adj.d_d[(i, j)], (fp - fm) / (2.0 * eps), 2e-4, "dD fd")
    });
}

// ---------------------------------------------------------------------------
// psi-scratch execution pipeline
// ---------------------------------------------------------------------------

fn random_adjoints(rng: &mut Rng, m: usize, d: usize) -> gp::Adjoints {
    gp::Adjoints {
        d_psi0: rng.normal(),
        d_c: random_matrix(rng, m, d, 1.0),
        d_d: random_matrix(rng, m, m, 1.0),
        d_kl: rng.normal(),
        d_kmm: Matrix::zeros(m, m),
        d_log_beta: 0.0,
    }
}

fn bits_f64(a: f64, b: f64, what: &str) -> Result<(), String> {
    if a.to_bits() == b.to_bits() {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (bitwise)"))
    }
}

fn bits_mat(a: &Matrix, b: &Matrix, what: &str) -> Result<(), String> {
    if (a.rows(), a.cols()) != (b.rows(), b.cols()) {
        return Err(format!("{what}: shape mismatch"));
    }
    for (x, y) in a.data().iter().zip(b.data()) {
        bits_f64(*x, *y, what)?;
    }
    Ok(())
}

/// Native executor built from shapes alone (the cluster-worker path).
fn shape_executor(m: usize, q: usize, d: usize) -> gparml::runtime::ShardExecutor {
    let cfg = ArtifactConfig {
        name: "prop".into(),
        m,
        q,
        d,
        cap: 64,
        block_n: 8,
        entries: BTreeMap::new(),
    };
    build_executor(&cfg, std::path::Path::new("artifacts")).expect("native executor from shapes")
}

#[test]
fn prop_scratch_pipeline_bitwise_equals_fresh() {
    check("scratch stats+grads == fresh bitwise", 12, |rng| {
        let (m, q, d) = (dim(rng, 2, 6), dim(rng, 1, 3), dim(rng, 1, 3));
        let n = dim(rng, 2, 18);
        let p = random_params(rng, m, q);
        let xmu = random_matrix(rng, n, q, 1.0);
        let xvar = Matrix::from_fn(n, q, |_, _| 0.05 + rng.uniform());
        let y = random_matrix(rng, n, d, 1.0);
        let adj = random_adjoints(rng, m, d);
        let mask = vec![1.0; n];
        let st_ref = kernel::shard_stats(&p, &xmu, &xvar, &y, &mask, 1.0);
        let (g_ref, dmu_ref, dvar_ref) = kernel::shard_grads_vjp(&p, &xmu, &xvar, &y, 1.0, &adj);
        // both the full Psi2 slab and the gated-off (recompute) mode
        for limit in [usize::MAX, 0] {
            let mut scratch = kernel::ShardScratch::with_slab_limit(limit);
            let st = kernel::shard_stats_into(&p, &xmu, &xvar, &y, &mask, 1.0, &mut scratch);
            bits_f64(st.a, st_ref.a, "a")?;
            bits_f64(st.psi0, st_ref.psi0, "psi0")?;
            bits_f64(st.kl, st_ref.kl, "kl")?;
            bits_f64(st.n, st_ref.n, "n")?;
            bits_mat(&st.c, &st_ref.c, "C")?;
            bits_mat(&st.d, &st_ref.d, "D")?;
            let (g, dmu, dvar) =
                kernel::shard_grads_vjp_cached(&p, &xmu, &xvar, &y, 1.0, &adj, &mut scratch);
            bits_mat(&g.d_z, &g_ref.d_z, "dZ")?;
            bits_f64(g.d_log_sf2, g_ref.d_log_sf2, "dlog_sf2")?;
            for (a, b) in g.d_log_ls.iter().zip(&g_ref.d_log_ls) {
                bits_f64(*a, *b, "dlog_ls")?;
            }
            bits_mat(&dmu, &dmu_ref, "dXmu")?;
            bits_mat(&dvar, &dvar_ref, "dXvar")?;
        }
        Ok(())
    });
}

#[test]
fn prop_stale_param_version_never_reused() {
    check("executor never reuses a stale psi cache", 10, |rng| {
        let (m, q, d) = (dim(rng, 2, 6), dim(rng, 1, 3), dim(rng, 1, 2));
        let n = dim(rng, 2, 12);
        let p1 = random_params(rng, m, q);
        let shard = ShardData {
            xmu: random_matrix(rng, n, q, 1.0),
            xvar: Matrix::from_fn(n, q, |_, _| 0.05 + rng.uniform()),
            y: random_matrix(rng, n, d, 1.0),
            kl_weight: 1.0,
        };
        let adj = random_adjoints(rng, m, d);
        let exec = shape_executor(m, q, d);

        // round 1 at version 1 / params p1 fills the cache
        let tok1 = exec.begin_eval(1);
        exec.shard_stats_cached(&tok1, &p1, &shard)
            .map_err(|e| e.to_string())?;

        // mutate ONE hyperparameter and move to version 2: the gradient
        // round must never consume the version-1 cache
        let mut p2 = p1.clone();
        match rng.below(3) {
            0 => p2.log_ls[rng.below(q)] += 0.25,
            1 => p2.log_sf2 += 0.25,
            _ => {
                let (i, j) = (rng.below(m), rng.below(q));
                p2.z[(i, j)] += 0.25;
            }
        }
        let tok2 = exec.begin_eval(2);
        let (g, local) = exec
            .shard_grads_cached(&tok2, &p2, &shard, &adj)
            .map_err(|e| e.to_string())?;
        if exec.cache_hits() != 0 {
            return Err("stale psi cache consumed across versions".into());
        }

        // bit-for-bit identical to a completely fresh executor at p2
        let fresh = shape_executor(m, q, d);
        let (gf, localf) = fresh
            .shard_grads(&p2, &shard, &adj)
            .map_err(|e| e.to_string())?;
        bits_mat(&g.d_z, &gf.d_z, "dZ")?;
        bits_f64(g.d_log_sf2, gf.d_log_sf2, "dlog_sf2")?;
        for (a, b) in g.d_log_ls.iter().zip(&gf.d_log_ls) {
            bits_f64(*a, *b, "dlog_ls")?;
        }
        bits_mat(&local.d_xmu, &localf.d_xmu, "dXmu")?;
        bits_mat(&local.d_xvar, &localf.d_xvar, "dXvar")?;

        // while a same-version gradient round IS served from the cache,
        // with the same bits
        let tok3 = exec.begin_eval(3);
        exec.shard_stats_cached(&tok3, &p2, &shard)
            .map_err(|e| e.to_string())?;
        let (g2, _) = exec
            .shard_grads_cached(&tok3, &p2, &shard, &adj)
            .map_err(|e| e.to_string())?;
        if exec.cache_hits() != 1 {
            return Err(format!("expected one cache hit, got {}", exec.cache_hits()));
        }
        bits_mat(&g2.d_z, &gf.d_z, "dZ (cache hit)")?;
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// optimiser
// ---------------------------------------------------------------------------

#[test]
fn prop_scg_descends_random_convex_quadratics() {
    check("SCG minimises random SPD quadratics", 15, |rng| {
        let n = dim(rng, 2, 8);
        let a = random_spd(rng, n, 0.5);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut obj = |x: &[f64]| {
            let ax = a.matvec(x);
            let f = 0.5
                * x.iter().zip(&ax).map(|(xi, axi)| xi * axi).sum::<f64>()
                - b.iter().zip(x).map(|(bi, xi)| bi * xi).sum::<f64>();
            let g: Vec<f64> = ax.iter().zip(&b).map(|(axi, bi)| axi - bi).collect();
            (f, g)
        };
        let x0: Vec<f64> = (0..n).map(|_| 3.0 * rng.normal()).collect();
        let mut scg = Scg::new(x0, &mut obj);
        for _ in 0..20 * n {
            scg.step(&mut obj);
        }
        // check gradient is (nearly) zero at the solution
        let (_, g) = obj(&scg.x().to_vec().as_slice());
        let gnorm: f64 = g.iter().map(|v| v * v).sum::<f64>().sqrt();
        if gnorm < 1e-4 {
            Ok(())
        } else {
            Err(format!("gradient norm {gnorm} after convergence"))
        }
    });
}

// ---------------------------------------------------------------------------
// util substrates
// ---------------------------------------------------------------------------

#[test]
fn prop_json_roundtrip_random_documents() {
    check("json parse(emit(v)) == v", 50, |rng| {
        fn random_json(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.below(3) } else { rng.below(5) } {
                0 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
                1 => Json::Bool(rng.flip(0.5)),
                2 => Json::Str(format!("s{}✓\"x\n", rng.below(1000))),
                3 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.below(4))
                        .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = random_json(rng, 3);
        let back = Json::parse(&v.to_string()).map_err(|e| e.to_string())?;
        if back == v {
            Ok(())
        } else {
            Err(format!("{v:?} != {back:?}"))
        }
    });
}

#[test]
fn prop_partition_is_exact_cover() {
    check("partition covers each point exactly once", 30, |rng| {
        let n = dim(rng, 1, 200);
        let k = dim(rng, 1, 16).min(n);
        let xmu = Matrix::from_fn(n, 1, |i, _| i as f64);
        let shards = partition(&xmu, &Matrix::zeros(n, 1), &Matrix::zeros(n, 1), 0.0, k);
        let mut seen = vec![false; n];
        for s in &shards {
            for i in 0..s.len() {
                let idx = s.xmu[(i, 0)] as usize;
                if seen[idx] {
                    return Err(format!("point {idx} covered twice"));
                }
                seen[idx] = true;
            }
        }
        if seen.iter().all(|s| *s) {
            Ok(())
        } else {
            Err("missing points".into())
        }
    });
}
