//! Multi-process cluster tests: a leader drives REAL spawned
//! `gparml worker` processes over localhost TCP and must (a) reproduce
//! the in-process Pool backend's training trace bit-for-bit on the same
//! seed, and (b) degrade onto the §5.2 drop-the-partial-term path —
//! without stalling — when a worker process is killed mid-run.

use std::net::TcpListener;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use gparml::cluster::TcpBackend;
use gparml::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use gparml::gp::{GlobalParams, MathMode};
use gparml::linalg::Matrix;
use gparml::runtime::ShardData;
use gparml::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Keep spawned workers from outliving a failed test.
struct Workers(Vec<Child>);

impl Drop for Workers {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

fn spawn_workers_with(n: usize, leader_addr: &str, extra: &[&str]) -> Workers {
    let bin = env!("CARGO_BIN_EXE_gparml");
    let art = artifacts_dir();
    Workers(
        (0..n)
            .map(|_| {
                Command::new(bin)
                    .args([
                        "worker",
                        "--connect",
                        leader_addr,
                        "--artifacts",
                        art.to_str().unwrap(),
                    ])
                    .args(extra)
                    .stdout(Stdio::null())
                    .stderr(Stdio::null())
                    .spawn()
                    .expect("spawning gparml worker process")
            })
            .collect(),
    )
}

fn spawn_workers(n: usize, leader_addr: &str) -> Workers {
    spawn_workers_with(n, leader_addr, &[])
}

fn regression_data(n: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let xmu = Matrix::from_fn(n, 2, |_, _| rng.range(-2.0, 2.0));
    let xvar = Matrix::zeros(n, 2);
    let y = Matrix::from_fn(n, 3, |i, j| {
        let x = xmu[(i, 0)];
        let f = match j {
            0 => x.sin(),
            1 => (1.3 * x).cos(),
            _ => 0.5 * x,
        };
        f + 0.05 * rng.normal()
    });
    (xmu, xvar, y)
}

fn init_params(seed: u64) -> GlobalParams {
    let mut rng = Rng::new(seed);
    GlobalParams {
        z: Matrix::from_fn(8, 2, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    }
}

fn config(workers: usize, model: ModelKind) -> TrainConfig {
    TrainConfig {
        artifact: "test".into(),
        artifacts_dir: artifacts_dir(),
        workers,
        model,
        global_opt: GlobalOpt::Scg,
        seed: 1,
        ..Default::default()
    }
}

/// Spawn `n` worker processes that dial our listener, and hand them
/// their shards during the handshake.
fn tcp_trainer(
    cfg: TrainConfig,
    params: GlobalParams,
    shards: Vec<ShardData>,
) -> (Trainer<TcpBackend>, Workers) {
    tcp_trainer_with(cfg, params, shards, &[])
}

/// [`tcp_trainer`] with extra `gparml worker` CLI flags (pins etc.).
fn tcp_trainer_with(
    cfg: TrainConfig,
    params: GlobalParams,
    shards: Vec<ShardData>,
    extra: &[&str],
) -> (Trainer<TcpBackend>, Workers) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind leader listener");
    let addr = listener.local_addr().unwrap().to_string();
    let workers = spawn_workers_with(cfg.workers, &addr, extra);
    let mut trainer =
        Trainer::accept_tcp(cfg, params, shards, &listener).expect("cluster bring-up");
    trainer.backend_mut().set_timeout(Duration::from_secs(30));
    trainer
        .backend_mut()
        .set_heartbeat_timeout(Duration::from_secs(5));
    (trainer, workers)
}

#[test]
fn tcp_cluster_matches_pool_backend_bitwise() {
    let (xmu, xvar, y) = regression_data(60, 3);
    let workers = 2;
    let iters = 6;
    let shards = partition(&xmu, &xvar, &y, 0.0, workers);

    // reference: in-process thread backend (psi cache on, the default)
    let mut pool_t = Trainer::new(
        config(workers, ModelKind::Regression),
        init_params(5),
        shards.clone(),
    )
    .unwrap();
    let pool_trace: Vec<f64> = (0..iters).map(|_| pool_t.step().unwrap()).collect();

    // forced-fresh reference: psi cache off, everything recomputed per
    // round — the cached round 2 must equal this recompute bit-for-bit
    let mut fresh_cfg = config(workers, ModelKind::Regression);
    fresh_cfg.psi_cache = false;
    let mut fresh_t = Trainer::new(fresh_cfg, init_params(5), shards.clone()).unwrap();
    let fresh_trace: Vec<f64> = (0..iters).map(|_| fresh_t.step().unwrap()).collect();
    for (i, (a, b)) in pool_trace.iter().zip(&fresh_trace).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "iteration {i}: cached F={a} vs forced-fresh F={b}"
        );
    }
    for (a, b) in pool_t.params.flatten().iter().zip(fresh_t.params.flatten()) {
        assert_eq!(a.to_bits(), b.to_bits(), "cached vs fresh params diverged");
    }

    // real processes over TCP, same seed, same shards
    let (mut tcp_t, procs) = tcp_trainer(
        config(workers, ModelKind::Regression),
        init_params(5),
        shards,
    );
    let tcp_trace: Vec<f64> = (0..iters).map(|_| tcp_t.step().unwrap()).collect();

    // the wire carries every f64 bit-for-bit and both backends reduce in
    // worker order, so the traces must be IDENTICAL, not just close
    for (i, (a, b)) in pool_trace.iter().zip(&tcp_trace).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "iteration {i}: pool F={a} vs tcp F={b}"
        );
    }
    for (a, b) in pool_t.params.flatten().iter().zip(tcp_t.params.flatten()) {
        assert_eq!(a.to_bits(), b.to_bits(), "final params diverged");
    }

    // the TCP rounds actually moved bytes, and telemetry recorded them
    let (tx, rx) = tcp_t.log.total_network_bytes();
    assert!(tx > 0 && rx > 0, "no network traffic recorded: {tx}/{rx}");
    let (pool_tx, pool_rx) = pool_t.log.total_network_bytes();
    assert_eq!((pool_tx, pool_rx), (0, 0), "in-process backend sent bytes?");

    // cache reuse is observable end-to-end, over the wire included: a
    // statistics round costs one psi pass per worker, a cached gradient
    // round zero; without the cache every round pays a pass
    for log in [&pool_t.log, &tcp_t.log] {
        for it in &log.iterations {
            assert_eq!(it.rounds.len() % 2, 0, "rounds come in stats/grads pairs");
            for (r, round) in it.rounds.iter().enumerate() {
                let expect = if r % 2 == 0 { workers as u64 } else { 0 };
                assert_eq!(
                    round.psi_recomputes, expect,
                    "iter {} round {r}: psi recomputes",
                    it.iter
                );
            }
        }
    }
    for it in &fresh_t.log.iterations {
        for (r, round) in it.rounds.iter().enumerate() {
            assert_eq!(
                round.psi_recomputes,
                workers as u64,
                "iter {} round {r}: forced-fresh must recompute every round",
                it.iter
            );
        }
    }

    drop(tcp_t); // sends Shutdown frames
    drop(procs);
}

#[test]
fn tcp_cluster_lvm_local_updates_match_pool_backend() {
    // the LVM path exercises worker-side state mutation (local Adam
    // steps) across the wire; the traces must still agree bit-for-bit
    let n = 40;
    let mut rng = Rng::new(8);
    let y = Matrix::from_fn(n, 3, |i, j| {
        let t = i as f64 / n as f64 * 4.0 - 2.0;
        match j {
            0 => t.sin(),
            1 => t.cos(),
            _ => 0.5 * t,
        }
    });
    let xmu = Matrix::from_fn(n, 2, |_, _| 0.5 * rng.normal());
    let xvar = Matrix::from_fn(n, 2, |_, _| 0.5);
    let shards = partition(&xmu, &xvar, &y, 1.0, 2);
    let iters = 4;

    let mut pool_t = Trainer::new(config(2, ModelKind::Lvm), init_params(9), shards.clone())
        .unwrap();
    let pool_trace: Vec<f64> = (0..iters).map(|_| pool_t.step().unwrap()).collect();

    // the LVM path also mutates the local parameters mid-evaluation
    // (cache invalidation on the workers); a forced-fresh run must still
    // agree bit-for-bit
    let mut fresh_cfg = config(2, ModelKind::Lvm);
    fresh_cfg.psi_cache = false;
    let mut fresh_t = Trainer::new(fresh_cfg, init_params(9), shards.clone()).unwrap();
    for (i, f) in pool_trace.iter().enumerate() {
        let g = fresh_t.step().unwrap();
        assert_eq!(f.to_bits(), g.to_bits(), "LVM iteration {i}: cached vs fresh");
    }

    let (mut tcp_t, procs) = tcp_trainer(config(2, ModelKind::Lvm), init_params(9), shards);
    let tcp_trace: Vec<f64> = (0..iters).map(|_| tcp_t.step().unwrap()).collect();

    for (i, (a, b)) in pool_trace.iter().zip(&tcp_trace).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "LVM iteration {i}: {a} vs {b}");
    }
    // the gathered local parameters went through local updates on the
    // worker processes and must match the thread backend's exactly
    let pool_locals = pool_t.gather_locals().unwrap();
    let tcp_locals = tcp_t.gather_locals().unwrap();
    assert_eq!(pool_locals.len(), tcp_locals.len());
    for ((pi, pm, pv), (ti, tm, tv)) in pool_locals.iter().zip(&tcp_locals) {
        assert_eq!(pi, ti, "gathered row indices diverged");
        assert_eq!(pm.max_abs_diff(tm), 0.0, "local means diverged");
        assert_eq!(pv.max_abs_diff(tv), 0.0, "local variances diverged");
    }
    let fresh_locals = fresh_t.gather_locals().unwrap();
    for ((_, pm, pv), (_, fm, fv)) in pool_locals.iter().zip(&fresh_locals) {
        assert_eq!(pm.max_abs_diff(fm), 0.0, "cached vs fresh local means");
        assert_eq!(pv.max_abs_diff(fv), 0.0, "cached vs fresh local variances");
    }
    drop(tcp_t);
    drop(procs);
}

/// Fast math mode end to end: the mode travels in the v3 `Init`,
/// both backends run the same fast kernels deterministically, so a
/// same-mode Pool and TCP cluster still produce bit-identical traces
/// (Fast relaxes cross-MODE equality, never cross-BACKEND equality).
#[test]
fn tcp_cluster_fast_mode_matches_pool_backend_bitwise() {
    let (xmu, xvar, y) = regression_data(60, 3);
    let workers = 2;
    let iters = 5;
    let shards = partition(&xmu, &xvar, &y, 0.0, workers);
    let mut cfg = config(workers, ModelKind::Regression);
    cfg.math_mode = MathMode::Fast;

    let mut pool_t = Trainer::new(cfg.clone(), init_params(5), shards.clone()).unwrap();
    let pool_trace: Vec<f64> = (0..iters).map(|_| pool_t.step().unwrap()).collect();

    let (mut tcp_t, procs) = tcp_trainer(cfg, init_params(5), shards.clone());
    let tcp_trace: Vec<f64> = (0..iters).map(|_| tcp_t.step().unwrap()).collect();

    for (i, (a, b)) in pool_trace.iter().zip(&tcp_trace).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "fast iteration {i}: pool F={a} vs tcp F={b}"
        );
    }
    for (a, b) in pool_t.params.flatten().iter().zip(tcp_t.params.flatten()) {
        assert_eq!(a.to_bits(), b.to_bits(), "fast final params diverged");
    }

    // telemetry records the mode on every round, and the psi cache
    // telemetry holds under Fast too (stats = 1 pass/worker, grads 0)
    for log in [&pool_t.log, &tcp_t.log] {
        for it in &log.iterations {
            for (r, round) in it.rounds.iter().enumerate() {
                assert_eq!(round.math_mode, MathMode::Fast, "round mode not recorded");
                let expect = if r % 2 == 0 { workers as u64 } else { 0 };
                assert_eq!(
                    round.psi_recomputes, expect,
                    "fast iter {} round {r}: psi recomputes",
                    it.iter
                );
            }
        }
    }

    // the distributed bound at fixed parameters must agree across the
    // modes within the Fast contract's tolerance (trajectories are NOT
    // compared: a single SCG line-search branch can amplify ulp-level
    // drift arbitrarily, which is exactly why Fast is a negotiated
    // cluster-wide policy rather than a per-node choice)
    let mut fast_cfg = config(workers, ModelKind::Regression);
    fast_cfg.math_mode = MathMode::Fast;
    let mut fast_eval_t = Trainer::new(fast_cfg, init_params(5), shards.clone()).unwrap();
    let mut strict_eval_t = Trainer::new(
        config(workers, ModelKind::Regression),
        init_params(5),
        shards,
    )
    .unwrap();
    let f_fast = fast_eval_t.evaluate().unwrap();
    let f_strict = strict_eval_t.evaluate().unwrap();
    // 1e-8 rather than the kernel-level 1e-9: the central assembly's
    // solves sit between the shard statistics and F, adding a
    // conditioning factor on top of the kernels' own drift
    assert!(
        ((f_fast - f_strict) / (1.0 + f_strict.abs())).abs() < 1e-8,
        "fast bound {f_fast} drifted from strict {f_strict}"
    );

    drop(tcp_t);
    drop(procs);
}

/// DESIGN.md §11: the intra-worker fill-thread count is a purely
/// PHYSICAL knob — every psi fill splits into fixed row ranges that are
/// a pure function of shard size and thread count, and all floating-
/// point accumulation stays sequential — so a strict-mode training
/// trace must be bit-for-bit identical at `--fill-threads` 1/2/4, both
/// in-process and over the wire (the count travels in the v7 `Init`
/// frame; a worker pinned to the matching count must bring up cleanly).
#[test]
fn fill_thread_count_never_changes_strict_traces() {
    let (xmu, xvar, y) = regression_data(60, 3);
    let workers = 2;
    let iters = 4;
    let shards = partition(&xmu, &xvar, &y, 0.0, workers);

    // reference: the sequential fill on the in-process Pool backend
    let mut ref_t = Trainer::new(
        config(workers, ModelKind::Regression),
        init_params(5),
        shards.clone(),
    )
    .unwrap();
    let reference: Vec<f64> = (0..iters).map(|_| ref_t.step().unwrap()).collect();

    for threads in [2usize, 4] {
        let mut cfg = config(workers, ModelKind::Regression);
        cfg.fill_threads = threads;
        let mut pool_t = Trainer::new(cfg, init_params(5), shards.clone()).unwrap();
        for (i, f) in reference.iter().enumerate() {
            let g = pool_t.step().unwrap();
            assert_eq!(
                f.to_bits(),
                g.to_bits(),
                "pool fill-threads {threads}, iteration {i}: F={f} vs F={g}"
            );
        }
        for (a, b) in ref_t.params.flatten().iter().zip(pool_t.params.flatten()) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "pool fill-threads {threads}: final params diverged"
            );
        }
    }

    // the same sweep over REAL worker processes: the count is
    // negotiated in the Init frame (workers unpinned at 1/2; pinned to
    // the matching count at 4, which must be accepted at bring-up)
    for threads in [1usize, 2, 4] {
        let mut cfg = config(workers, ModelKind::Regression);
        cfg.fill_threads = threads;
        let (mut tcp_t, procs) = if threads == 4 {
            tcp_trainer_with(cfg, init_params(5), shards.clone(), &["--fill-threads", "4"])
        } else {
            tcp_trainer(cfg, init_params(5), shards.clone())
        };
        for (i, f) in reference.iter().enumerate() {
            let g = tcp_t.step().unwrap();
            assert_eq!(
                f.to_bits(),
                g.to_bits(),
                "tcp fill-threads {threads}, iteration {i}: F={f} vs F={g}"
            );
        }
        for (a, b) in ref_t.params.flatten().iter().zip(tcp_t.params.flatten()) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "tcp fill-threads {threads}: final params diverged"
            );
        }
        drop(tcp_t);
        drop(procs);
    }
}

/// DESIGN.md §11: like `--math-mode`, a worker pinned to a fill-thread
/// count answers a mismatching leader's `Init` with an error, and the
/// leader's bring-up reports it (mixed-setting clusters fail loudly,
/// they never run).
#[test]
fn leader_refuses_mismatched_fill_thread_pin() {
    let (xmu, xvar, y) = regression_data(20, 4);
    let shards = partition(&xmu, &xvar, &y, 0.0, 1);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind leader listener");
    let addr = listener.local_addr().unwrap().to_string();
    let procs = spawn_workers_with(1, &addr, &["--fill-threads", "4"]);

    let mut cfg = config(1, ModelKind::Regression);
    cfg.fill_threads = 2;
    let err = Trainer::accept_tcp(cfg, init_params(5), shards, &listener)
        .err()
        .expect("leader must refuse a worker pinned to a different fill-thread count");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("fill threads") || msg.contains("pinned"),
        "bring-up error does not explain the fill-thread mismatch: {msg}"
    );
    drop(procs);
}

/// Mixed-mode bring-up must fail loudly: a worker pinned to Fast
/// (`gparml worker --math-mode fast`) answers a Strict leader's `Init`
/// with an error, and the leader's bring-up reports it.
#[test]
fn strict_leader_refuses_fast_pinned_worker() {
    let (xmu, xvar, y) = regression_data(20, 4);
    let shards = partition(&xmu, &xvar, &y, 0.0, 1);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind leader listener");
    let addr = listener.local_addr().unwrap().to_string();
    let procs = spawn_workers_with(1, &addr, &["--math-mode", "fast"]);

    let err = Trainer::accept_tcp(
        config(1, ModelKind::Regression),
        init_params(5),
        shards,
        &listener,
    )
    .err()
    .expect("strict leader must refuse a fast-pinned worker");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("math mode") || msg.contains("pinned"),
        "bring-up error does not explain the mode mismatch: {msg}"
    );
    drop(procs);
}

#[test]
fn killing_a_worker_mid_run_degrades_without_stalling() {
    let (xmu, xvar, y) = regression_data(72, 10);
    let workers = 3;
    let shards = partition(&xmu, &xvar, &y, 0.0, workers);
    // probe liveness every step so the kill is caught by the heartbeat
    // membership path (mid-round deaths are covered by the map rounds)
    let mut cfg = config(workers, ModelKind::Regression);
    cfg.heartbeat_secs = 0.0;
    let (mut t, mut procs) = tcp_trainer(cfg, init_params(11), shards);

    // healthy start
    for _ in 0..2 {
        t.step().unwrap();
    }
    assert!(t.dead_workers().is_empty());

    // kill one worker process outright (SIGKILL — no goodbye frame)
    procs.0[1].kill().expect("kill worker process");
    procs.0[1].wait().expect("reap worker process");

    // the run must keep going on the survivors without stalling: the
    // dead node's partial term is dropped (§5.2), not waited for
    let t0 = Instant::now();
    let mut f_end = f64::NAN;
    for _ in 0..3 {
        f_end = t.step().unwrap();
    }
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "cluster stalled after worker death"
    );
    assert!(f_end.is_finite(), "bound diverged after worker death");

    // exactly one worker was declared dead, and the failure was logged
    assert_eq!(t.dead_workers().len(), 1, "dead set: {:?}", t.dead_workers());
    let failed_total: Vec<usize> = t
        .log
        .iterations
        .iter()
        .skip(2)
        .flat_map(|i| i.failed_workers.iter().copied())
        .collect();
    assert!(
        !failed_total.is_empty(),
        "worker death never reached the failure log"
    );

    // the survivors still serve evaluation and prediction
    assert!(t.evaluate().unwrap().is_finite());
    let xt = Matrix::from_fn(5, 2, |_, _| 0.3);
    let (mean, var) = t.predict(&xt, &Matrix::zeros(5, 2)).unwrap();
    assert_eq!(mean.rows(), 5);
    assert_eq!(var.len(), 5);

    drop(t);
    drop(procs);
}
