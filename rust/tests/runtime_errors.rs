//! Failure-path coverage: the runtime and coordinator must fail loudly
//! and informatively on bad artifacts, shape mismatches and invalid
//! configurations — not deep inside the C++ layer.

use std::path::PathBuf;

use gparml::coordinator::{partition, TrainConfig, Trainer};
use gparml::gp::GlobalParams;
use gparml::linalg::Matrix;
use gparml::runtime::{Manifest, ShardData, ShardExecutor};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("gparml_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_a_clean_error() {
    let err = Manifest::load(&tmpdir("nomanifest")).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest.json"), "unhelpful error: {msg}");
}

#[test]
fn unknown_config_lists_available_ones() {
    let man = Manifest::load(&artifacts_dir()).unwrap();
    let err = man.config("nonexistent").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("nonexistent") && msg.contains("test"), "{msg}");
}

/// PJRT-only: the native executor never opens the HLO files.
#[cfg(feature = "pjrt")]
#[test]
fn corrupt_hlo_fails_at_compile_with_path() {
    let dir = tmpdir("corrupt");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"dtype":"f64","configs":{"bad":{"m":4,"q":2,"d":3,"B":16,"block_n":8,
           "entries":{"shard_stats":"bad.hlo.txt","shard_grads":"bad.hlo.txt",
                      "kmm_grads":"bad.hlo.txt","predict":"bad.hlo.txt"}}}}"#,
    )
    .unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "this is not HLO").unwrap();
    let man = Manifest::load(&dir).unwrap();
    let err = match ShardExecutor::new(&man, "bad") {
        Err(e) => e,
        Ok(_) => panic!("corrupt HLO compiled"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("bad.hlo.txt"), "error lost the artifact path: {msg}");
}

#[test]
fn params_shape_mismatch_rejected_before_execution() {
    let man = Manifest::load(&artifacts_dir()).unwrap();
    let exec = ShardExecutor::new(&man, "test").unwrap(); // m=8, q=2
    let wrong = GlobalParams {
        z: Matrix::zeros(5, 2), // wrong m
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 0.0,
    };
    let shard = ShardData {
        xmu: Matrix::zeros(4, 2),
        xvar: Matrix::zeros(4, 2),
        y: Matrix::zeros(4, 3),
        kl_weight: 0.0,
    };
    let err = exec.shard_stats(&wrong, &shard).unwrap_err();
    assert!(format!("{err:#}").contains("match artifact config"));
}

#[test]
fn trainer_rejects_mismatched_shard_count() {
    let cfg = TrainConfig {
        artifact: "test".into(),
        artifacts_dir: artifacts_dir(),
        workers: 3,
        ..Default::default()
    };
    let params = GlobalParams {
        z: Matrix::zeros(8, 2),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 0.0,
    };
    let xmu = Matrix::zeros(10, 2);
    let shards = partition(&xmu, &Matrix::zeros(10, 2), &Matrix::zeros(10, 3), 0.0, 2);
    let err = match Trainer::new(cfg, params, shards) {
        Err(e) => e,
        Ok(_) => panic!("mismatched shard count accepted"),
    };
    assert!(format!("{err:#}").contains("one shard per worker"));
}

#[test]
fn trainer_rejects_wrong_artifact_shape() {
    let cfg = TrainConfig {
        artifact: "test".into(), // m=8
        artifacts_dir: artifacts_dir(),
        workers: 1,
        ..Default::default()
    };
    let params = GlobalParams {
        z: Matrix::zeros(16, 2), // m=16 mismatch
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 0.0,
    };
    let xmu = Matrix::zeros(8, 2);
    let shards = partition(&xmu, &Matrix::zeros(8, 2), &Matrix::zeros(8, 3), 0.0, 1);
    let err = match Trainer::new(cfg, params, shards) {
        Err(e) => e,
        Ok(_) => panic!("wrong artifact shape accepted"),
    };
    assert!(format!("{err:#}").contains("does not match artifact"));
}

#[test]
fn empty_shard_yields_zero_stats() {
    let man = Manifest::load(&artifacts_dir()).unwrap();
    let exec = ShardExecutor::new(&man, "test").unwrap();
    let params = GlobalParams {
        z: Matrix::zeros(8, 2),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 0.0,
    };
    let shard = ShardData {
        xmu: Matrix::zeros(0, 2),
        xvar: Matrix::zeros(0, 2),
        y: Matrix::zeros(0, 3),
        kl_weight: 0.0,
    };
    let st = exec.shard_stats(&params, &shard).unwrap();
    assert_eq!(st.n, 0.0);
    assert_eq!(st.a, 0.0);
    assert_eq!(st.d.max_abs(), 0.0);
}
