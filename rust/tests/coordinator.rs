//! End-to-end coordinator tests: distributed training over real PJRT
//! worker nodes improves the bound, matches the sequential computation
//! exactly, and degrades gracefully under failure injection.

use std::path::PathBuf;

use gparml::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use gparml::gp::{kernel, GlobalParams};
use gparml::linalg::Matrix;
use gparml::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Small 1D regression problem matching the "test" artifact (m=8, q=2,
/// d=3): targets are smooth functions of the first input dimension.
fn regression_data(n: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let xmu = Matrix::from_fn(n, 2, |_, _| rng.range(-2.0, 2.0));
    let xvar = Matrix::zeros(n, 2);
    let y = Matrix::from_fn(n, 3, |i, j| {
        let x = xmu[(i, 0)];
        let f = match j {
            0 => x.sin(),
            1 => (1.3 * x).cos(),
            _ => 0.5 * x,
        };
        f + 0.05 * rng.normal()
    });
    (xmu, xvar, y)
}

fn init_params(seed: u64) -> GlobalParams {
    let mut rng = Rng::new(seed);
    GlobalParams {
        z: Matrix::from_fn(8, 2, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    }
}

fn config(workers: usize) -> TrainConfig {
    TrainConfig {
        artifact: "test".into(),
        artifacts_dir: artifacts_dir(),
        workers,
        model: ModelKind::Regression,
        global_opt: GlobalOpt::Scg,
        seed: 1,
        ..Default::default()
    }
}

/// Fast math without the psi cache is the one invalid configuration
/// (the forced-fresh path IS the strict reference); bring-up must
/// reject it before any backend exists.
#[test]
fn fast_math_without_psi_cache_is_rejected_at_bringup() {
    let (xmu, xvar, y) = regression_data(24, 7);
    let shards = partition(&xmu, &xvar, &y, 0.0, 2);
    let mut cfg = config(2);
    cfg.math_mode = gparml::gp::MathMode::Fast;
    cfg.psi_cache = false;
    let err = Trainer::new(cfg, init_params(2), shards).err().expect("must be rejected");
    let msg = format!("{err:#}");
    assert!(msg.contains("psi_cache"), "unhelpful error: {msg}");
}

/// A Fast-mode in-process cluster trains end to end and improves the
/// bound just like Strict — the policy changes rounding, not the
/// algorithm.
#[test]
fn fast_mode_training_improves_bound() {
    let (xmu, xvar, y) = regression_data(96, 0);
    let shards = partition(&xmu, &xvar, &y, 0.0, 3);
    let mut cfg = config(3);
    cfg.math_mode = gparml::gp::MathMode::Fast;
    let mut t = Trainer::new(cfg, init_params(2), shards).unwrap();
    let f0 = t.evaluate().unwrap();
    let f_end = t.train(10).unwrap();
    assert!(
        f_end > f0 + 1.0,
        "fast-mode SCG failed to improve the bound: {f0} -> {f_end}"
    );
    for it in &t.log.iterations {
        for r in &it.rounds {
            assert_eq!(r.math_mode, gparml::gp::MathMode::Fast);
        }
    }
}

#[test]
fn distributed_training_improves_bound() {
    let (xmu, xvar, y) = regression_data(96, 0);
    let shards = partition(&xmu, &xvar, &y, 0.0, 3);
    let mut t = Trainer::new(config(3), init_params(2), shards).unwrap();
    let f0 = t.evaluate().unwrap();
    let f_end = t.train(15).unwrap();
    assert!(
        f_end > f0 + 1.0,
        "SCG failed to improve the bound: {f0} -> {f_end}"
    );
    // telemetry recorded every iteration with both rounds
    assert_eq!(t.log.iterations.len(), 15);
    assert!(t.log.iterations.iter().all(|i| i.rounds.len() >= 2));
}

#[test]
fn bound_is_identical_for_any_worker_count() {
    // The distributed bound/gradient must not depend on the sharding —
    // the paper's exactness claim (no approximation from distribution).
    let (xmu, xvar, y) = regression_data(60, 3);
    let mut vals = Vec::new();
    for workers in [1, 2, 4] {
        let shards = partition(&xmu, &xvar, &y, 0.0, workers);
        let mut t = Trainer::new(config(workers), init_params(5), shards).unwrap();
        vals.push(t.evaluate().unwrap());
    }
    assert!(
        (vals[0] - vals[1]).abs() < 1e-9 && (vals[0] - vals[2]).abs() < 1e-9,
        "bound depends on sharding: {vals:?}"
    );
}

#[test]
fn training_trace_is_deterministic_for_fixed_seed() {
    let (xmu, xvar, y) = regression_data(48, 4);
    let run = || {
        let shards = partition(&xmu, &xvar, &y, 0.0, 2);
        let mut t = Trainer::new(config(2), init_params(7), shards).unwrap();
        t.train(5).unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a, b, "nondeterministic training trace");
}

#[test]
fn lvm_training_improves_bound_and_moves_locals() {
    // 1D latent structure embedded in 3D observations
    let n = 64;
    let mut rng = Rng::new(8);
    let t_lat: Vec<f64> = (0..n).map(|i| i as f64 / n as f64 * 4.0 - 2.0).collect();
    let y = Matrix::from_fn(n, 3, |i, j| {
        let t = t_lat[i];
        match j {
            0 => t.sin(),
            1 => t.cos(),
            _ => 0.5 * t,
        }
    });
    // init latents randomly (PCA init is exercised in the experiments)
    let xmu = Matrix::from_fn(n, 2, |_, _| 0.5 * rng.normal());
    let xvar = Matrix::from_fn(n, 2, |_, _| 0.5);
    let shards = partition(&xmu, &xvar, &y, 1.0, 2);
    let mut cfg = config(2);
    cfg.model = ModelKind::Lvm;
    cfg.local_lr = 0.05;
    let mut t = Trainer::new(cfg, init_params(9), shards).unwrap();
    let f0 = t.evaluate().unwrap();
    let f_end = t.train(25).unwrap();
    assert!(f_end > f0, "LVM bound did not improve: {f0} -> {f_end}");
    // locals actually moved (compared at their original dataset rows)
    let locals = t.gather_locals().unwrap();
    let mut moved = false;
    for (ids, mu, _) in &locals {
        for (i, &orig) in ids.iter().enumerate() {
            if (mu[(i, 0)] - xmu[(orig, 0)]).abs() > 1e-4 {
                moved = true;
            }
        }
    }
    assert!(moved, "worker-local q(X) parameters never updated");
}

#[test]
fn failure_injection_drops_partials_but_training_survives() {
    let (xmu, xvar, y) = regression_data(80, 10);
    let shards = partition(&xmu, &xvar, &y, 0.0, 4);
    let mut cfg = config(4);
    cfg.failure_rate = 0.25; // aggressive: ~1 node down per iteration
    cfg.seed = 42;
    let mut t = Trainer::new(cfg, init_params(11), shards).unwrap();
    let f = t.train(10).unwrap();
    assert!(f.is_finite());
    let total_failures: usize = t
        .log
        .iterations
        .iter()
        .map(|i| i.failed_workers.len())
        .sum();
    assert!(
        total_failures > 0,
        "failure injection at 25% never dropped a node in 10 iterations"
    );
    // dropped nodes must show zero compute time in the round timings
    for it in &t.log.iterations {
        for &k in &it.failed_workers {
            for r in &it.rounds {
                assert_eq!(r.worker_secs[k], 0.0);
            }
        }
    }
}

#[test]
fn predictions_from_cluster_match_native_path() {
    let (xmu, xvar, y) = regression_data(50, 12);
    let shards = partition(&xmu, &xvar, &y, 0.0, 2);
    let mut t = Trainer::new(config(2), init_params(13), shards).unwrap();
    t.train(5).unwrap();

    let mut rng = Rng::new(14);
    let xt = Matrix::from_fn(9, 2, |_, _| rng.range(-2.0, 2.0));
    let xt_var = Matrix::zeros(9, 2);
    let (mean_c, var_c) = t.predict(&xt, &xt_var).unwrap();

    // native recomputation from gathered state
    let stats = t.current_stats().unwrap();
    let kmm = kernel::kmm(&t.params, 1e-6);
    let w = gparml::gp::bound::posterior_weights(&stats, &kmm, t.params.log_beta).unwrap();
    let (mean_n, var_n) = gparml::gp::bound::predict_native(&t.params, &w, &xt, &xt_var);
    assert!(mean_c.max_abs_diff(&mean_n) < 1e-9);
    for (a, b) in var_c.iter().zip(&var_n) {
        assert!((a - b).abs() < 1e-9);
    }
}

#[test]
fn decommission_preserves_exactness() {
    // After a node dies permanently and its shard is re-assigned to the
    // survivors, the bound must equal the full-data bound exactly (the
    // reassign strategy trades a pause for exactness — ablation A3).
    let (xmu, xvar, y) = regression_data(72, 20);
    let shards = partition(&xmu, &xvar, &y, 0.0, 4);
    let mut t = Trainer::new(config(4), init_params(21), shards).unwrap();
    let f_before = t.evaluate().unwrap();
    t.decommission(1).unwrap();
    let f_after = t.evaluate().unwrap();
    assert!(
        (f_before - f_after).abs() < 1e-9 * (1.0 + f_before.abs()),
        "re-sharding changed the bound: {f_before} vs {f_after}"
    );
    assert_eq!(t.dead_workers(), vec![1]);
    // training continues on the reduced cluster
    let f_end = t.train(5).unwrap();
    assert!(f_end.is_finite() && f_end >= f_after - 1e-6);
    // cannot decommission twice
    assert!(t.decommission(1).is_err());
}

#[test]
fn decommission_last_worker_refused() {
    let (xmu, xvar, y) = regression_data(30, 22);
    let shards = partition(&xmu, &xvar, &y, 0.0, 2);
    let mut t = Trainer::new(config(2), init_params(23), shards).unwrap();
    t.decommission(0).unwrap();
    assert!(t.decommission(1).is_err(), "must keep at least one node");
}

#[test]
fn adam_global_opt_also_trains() {
    let (xmu, xvar, y) = regression_data(60, 15);
    let shards = partition(&xmu, &xvar, &y, 0.0, 2);
    let mut cfg = config(2);
    cfg.global_opt = GlobalOpt::Adam { lr: 0.05 };
    let mut t = Trainer::new(cfg, init_params(16), shards).unwrap();
    let f0 = t.evaluate().unwrap();
    let f = t.train(30).unwrap();
    assert!(f > f0, "Adam ablation failed to improve: {f0} -> {f}");
}
