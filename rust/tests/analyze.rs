//! The repo passes its own lint engine (DESIGN.md §14): running
//! `gparml analyze` over this checkout with the committed allowlist
//! must produce zero unallowed findings, every allowlist entry must
//! still earn its keep, and the engine must actually be looking at the
//! sources (file count, known-file coverage).

use std::path::{Path, PathBuf};

use gparml::analyze::{allowlist::Allowlist, analyze_repo, RULE_IDS};

fn repo_root() -> PathBuf {
    // CARGO_MANIFEST_DIR is <root>/rust
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .to_path_buf()
}

#[test]
fn repo_is_clean_under_its_own_analyzer() {
    let root = repo_root();
    let allowlist = Allowlist::load(&root.join("analyze-allowlist.toml"))
        .expect("committed allowlist parses");
    let report = analyze_repo(&root, &allowlist).expect("analysis runs");

    assert!(
        report.findings.is_empty(),
        "unallowed findings — fix them or justify each in analyze-allowlist.toml:\n{:#?}",
        report.findings
    );
    assert!(
        report.unused_allows.is_empty(),
        "stale allowlist entries (matched nothing): {:?}",
        report.unused_allows
    );
    // the two sanctioned drain-sweep holds are present and justified
    assert_eq!(report.allowed.len(), 2, "{:#?}", report.allowed);
    for (f, reason) in &report.allowed {
        assert_eq!(f.rule, "lock-hygiene");
        assert!(f.snippet.contains("conn.shutdown"), "{f:?}");
        assert!(!reason.is_empty());
    }
    // sanity: the engine really walked the tree
    assert!(report.files > 50, "only {} files analysed", report.files);
}

#[test]
fn analyzer_without_allowlist_reports_only_the_sanctioned_holds() {
    let report = analyze_repo(&repo_root(), &Allowlist::default()).expect("analysis runs");
    assert_eq!(
        report.findings.len(),
        2,
        "expected exactly the two drain-sweep holds:\n{:#?}",
        report.findings
    );
    assert!(report.findings.iter().all(|f| f.rule == "lock-hygiene"));
    assert!(RULE_IDS.contains(&"lock-hygiene"));
}
