//! Out-of-core store integration tests (DESIGN.md §13): a strict-mode
//! training run brought up by STREAMING a packed on-disk store must be
//! bit-for-bit identical to one brought up from the same data
//! materialised in memory — on the in-process Pool backend across
//! chunk sizes, over real worker processes on TCP, and on the wire-v9
//! worker-local `shard_ref` path (no data rows on the wire at all).
//! A tampered manifest checksum must reject bring-up, not train.

use std::net::TcpListener;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use gparml::cluster::wire::ShardRef;
use gparml::coordinator::{
    partition, GlobalOpt, ModelKind, StreamConfig, TrainConfig, Trainer,
};
use gparml::gp::GlobalParams;
use gparml::linalg::Matrix;
use gparml::store::{InMemorySource, ShardedDiskSource, SplitColumns, StoreWriter};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Keep spawned workers from outliving a failed test.
struct Workers(Vec<Child>);

impl Drop for Workers {
    fn drop(&mut self) {
        for c in &mut self.0 {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

fn spawn_workers(n: usize, leader_addr: &str) -> Workers {
    let bin = env!("CARGO_BIN_EXE_gparml");
    let art = artifacts_dir();
    Workers(
        (0..n)
            .map(|_| {
                Command::new(bin)
                    .args([
                        "worker",
                        "--connect",
                        leader_addr,
                        "--artifacts",
                        art.to_str().unwrap(),
                    ])
                    .stdout(Stdio::null())
                    .stderr(Stdio::null())
                    .spawn()
                    .expect("spawning gparml worker process")
            })
            .collect(),
    )
}

fn init_params(seed: u64) -> GlobalParams {
    let mut rng = gparml::util::rng::Rng::new(seed);
    GlobalParams {
        z: Matrix::from_fn(8, 2, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    }
}

fn config(workers: usize) -> TrainConfig {
    TrainConfig {
        artifact: "test".into(),
        artifacts_dir: artifacts_dir(),
        workers,
        model: ModelKind::Regression,
        global_opt: GlobalOpt::Scg,
        seed: 1,
        ..Default::default()
    }
}

/// A 60 x 5 regression dataset in STORE layout: columns 0-1 are the
/// inputs, 2-4 the outputs. Built as one matrix so the materialised
/// reference and every store reader start from identical f64 bits.
fn dataset() -> Matrix {
    let mut rng = gparml::util::rng::Rng::new(3);
    let mut full = Matrix::zeros(60, 5);
    for i in 0..60 {
        let x0 = rng.range(-2.0, 2.0);
        let x1 = rng.range(-2.0, 2.0);
        full[(i, 0)] = x0;
        full[(i, 1)] = x1;
        full[(i, 2)] = x0.sin() + 0.05 * rng.normal();
        full[(i, 3)] = (1.3 * x0).cos() + 0.05 * rng.normal();
        full[(i, 4)] = 0.5 * x1 + 0.05 * rng.normal();
    }
    full
}

/// The materialised split of [`dataset`] for `partition`-based bring-up.
fn split(full: &Matrix) -> (Matrix, Matrix, Matrix) {
    let n = full.rows();
    let xmu = Matrix::from_fn(n, 2, |i, j| full[(i, j)]);
    let xvar = Matrix::zeros(n, 2);
    let y = Matrix::from_fn(n, 3, |i, j| full[(i, 2 + j)]);
    (xmu, xvar, y)
}

/// Pack [`dataset`] into a fresh store directory with the given shard
/// size, appending in deliberately unaligned chunks to exercise the
/// writer's rebuffering.
fn pack(name: &str, full: &Matrix, shard_rows: usize) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gpds_it_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut w = StoreWriter::create(&dir, 2, shard_rows, None).unwrap();
    let head = Matrix::from_fn(37, 5, |i, j| full[(i, j)]);
    let tail = Matrix::from_fn(23, 5, |i, j| full[(37 + i, j)]);
    w.append(&head).unwrap();
    w.append(&tail).unwrap();
    w.finish().unwrap();
    dir
}

fn run_trace<B: gparml::cluster::Backend>(t: &mut Trainer<B>, iters: usize) -> Vec<f64> {
    (0..iters).map(|_| t.step().unwrap()).collect()
}

fn assert_bitwise(label: &str, reference: &[f64], got: &[f64]) {
    for (i, (a, b)) in reference.iter().zip(got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{label} iteration {i}: F={a} vs F={b}");
    }
}

fn assert_params_bitwise<A: gparml::cluster::Backend, B: gparml::cluster::Backend>(
    label: &str,
    a: &Trainer<A>,
    b: &Trainer<B>,
) {
    for (x, y) in a.params.flatten().iter().zip(b.params.flatten()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: final params diverged");
    }
}

/// Worker-local shard refs for a store whose shards align 1:1 with the
/// worker partition.
fn shard_refs(src: &ShardedDiskSource) -> Vec<ShardRef> {
    src.manifest()
        .shards
        .iter()
        .enumerate()
        .map(|(i, e)| ShardRef {
            path: src.shard_path(i).to_str().unwrap().to_string(),
            checksum: e.checksum,
            rows: e.rows as u32,
            x_cols: 2,
            kl_weight: 0.0,
        })
        .collect()
}

/// Pool backend: a store streamed at ANY chunk size — and the in-memory
/// source, and the worker-local shard_ref path — must reproduce the
/// materialised bring-up's training trace bit-for-bit. shard_rows = 17
/// is deliberately unaligned with every chunk size AND with the 30/30
/// worker partition, so chunks cross shard boundaries both ways.
#[test]
fn streamed_store_bringup_matches_materialised_pool_training_bitwise() {
    let full = dataset();
    let (xmu, xvar, y) = split(&full);
    let workers = 2;
    let iters = 6;

    let mut ref_t = Trainer::new(
        config(workers),
        init_params(5),
        partition(&xmu, &xvar, &y, 0.0, workers),
    )
    .unwrap();
    let reference = run_trace(&mut ref_t, iters);

    let dir = pack("pool", &full, 17);
    let src = ShardedDiskSource::open(&dir).unwrap();
    let mapper = SplitColumns { x_cols: 2 };
    for chunk_rows in [1usize, 7, 64] {
        let stream = StreamConfig {
            source: &src,
            mapper: &mapper,
            chunk_rows,
            kl_weight: 0.0,
            shard_refs: None,
        };
        let mut t = Trainer::new_streaming(config(workers), init_params(5), &stream).unwrap();
        let trace = run_trace(&mut t, iters);
        assert_bitwise(&format!("disk chunk_rows={chunk_rows}"), &reference, &trace);
        assert_params_bitwise(&format!("disk chunk_rows={chunk_rows}"), &ref_t, &t);
    }

    // the in-memory source through the SAME streaming bring-up
    let mem = InMemorySource::new(full.clone());
    let stream = StreamConfig {
        source: &mem,
        mapper: &mapper,
        chunk_rows: 13,
        kl_weight: 0.0,
        shard_refs: None,
    };
    let mut t = Trainer::new_streaming(config(workers), init_params(5), &stream).unwrap();
    assert_bitwise("in-memory source", &reference, &run_trace(&mut t, iters));
    assert_params_bitwise("in-memory source", &ref_t, &t);

    // worker-local load: a 30-row-shard store aligns 1:1 with the
    // 30/30 partition, so each (in-process) worker reads and verifies
    // its own shard file — same trace, zero data rows through bring-up
    let adir = pack("pool_aligned", &full, 30);
    let asrc = ShardedDiskSource::open(&adir).unwrap();
    let refs = shard_refs(&asrc);
    assert_eq!(refs.len(), workers, "fixture must align shards to workers");
    let stream = StreamConfig {
        source: &asrc,
        mapper: &mapper,
        chunk_rows: 9,
        kl_weight: 0.0,
        shard_refs: Some(refs),
    };
    let mut t = Trainer::new_streaming(config(workers), init_params(5), &stream).unwrap();
    assert_bitwise("pool shard_ref", &reference, &run_trace(&mut t, iters));
    assert_params_bitwise("pool shard_ref", &ref_t, &t);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&adir).ok();
}

/// Real worker processes over TCP: both the leader-streamed bring-up
/// (rows chunked over the wire) and the v9 shard_ref bring-up (each
/// worker process reads its own shard file) must reproduce the
/// materialised Pool trace bit-for-bit.
#[test]
fn tcp_streamed_and_shard_ref_bringup_match_pool_bitwise() {
    let full = dataset();
    let (xmu, xvar, y) = split(&full);
    let workers = 2;
    let iters = 4;

    let mut ref_t = Trainer::new(
        config(workers),
        init_params(5),
        partition(&xmu, &xvar, &y, 0.0, workers),
    )
    .unwrap();
    let reference = run_trace(&mut ref_t, iters);

    let dir = pack("tcp", &full, 30);
    let src = ShardedDiskSource::open(&dir).unwrap();
    let mapper = SplitColumns { x_cols: 2 };
    let refs = shard_refs(&src);
    assert_eq!(refs.len(), workers, "fixture must align shards to workers");

    for (label, shard_refs) in [("tcp streamed", None), ("tcp shard_ref", Some(refs))] {
        let stream = StreamConfig {
            source: &src,
            mapper: &mapper,
            chunk_rows: 7,
            kl_weight: 0.0,
            shard_refs,
        };
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind leader listener");
        let addr = listener.local_addr().unwrap().to_string();
        let procs = spawn_workers(workers, &addr);
        let mut t =
            Trainer::accept_tcp_streaming(config(workers), init_params(5), &stream, &listener)
                .expect("streamed cluster bring-up");
        t.backend_mut().set_timeout(Duration::from_secs(30));
        t.backend_mut().set_heartbeat_timeout(Duration::from_secs(5));
        let trace = run_trace(&mut t, iters);
        assert_bitwise(label, &reference, &trace);
        assert_params_bitwise(label, &ref_t, &t);
        let (tx, rx) = t.log.total_network_bytes();
        assert!(tx > 0 && rx > 0, "{label}: no network traffic recorded");
        drop(t); // sends Shutdown frames
        drop(procs);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A shard_ref whose checksum disagrees with the file on disk must
/// reject bring-up with an error that names the mismatch — a worker
/// never trains on rows it could not verify. (WorkerNode::build is
/// shared by the Pool and TCP backends, so the Pool covers the
/// verification logic itself; the TCP leg proves worker-process Init
/// errors propagate into the leader's bring-up error.)
#[test]
fn tampered_shard_ref_checksum_rejects_bringup() {
    let full = dataset();
    let dir = pack("tamper", &full, 30);
    let src = ShardedDiskSource::open(&dir).unwrap();
    let mapper = SplitColumns { x_cols: 2 };
    let mut refs = shard_refs(&src);
    refs[1].checksum ^= 1;

    let stream = StreamConfig {
        source: &src,
        mapper: &mapper,
        chunk_rows: 9,
        kl_weight: 0.0,
        shard_refs: Some(refs.clone()),
    };
    let err = Trainer::new_streaming(config(2), init_params(5), &stream)
        .err()
        .expect("pool bring-up must reject a tampered shard_ref checksum");
    let msg = format!("{err:#}");
    assert!(msg.contains("checksum mismatch"), "unexplained rejection: {msg}");

    // same tampered refs over real worker processes: the worker's Init
    // error must surface as the leader's bring-up error
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind leader listener");
    let addr = listener.local_addr().unwrap().to_string();
    let procs = spawn_workers(2, &addr);
    let stream = StreamConfig {
        source: &src,
        mapper: &mapper,
        chunk_rows: 9,
        kl_weight: 0.0,
        shard_refs: Some(refs),
    };
    let err = Trainer::accept_tcp_streaming(config(2), init_params(5), &stream, &listener)
        .err()
        .expect("tcp bring-up must reject a tampered shard_ref checksum");
    let msg = format!("{err:#}");
    assert!(msg.contains("checksum"), "unexplained tcp rejection: {msg}");
    drop(procs);
    std::fs::remove_dir_all(&dir).ok();
}

/// Pack -> open -> verify -> read_all across degenerate and chunky
/// shapes: the store must hand back exactly the f64 bits that went in.
#[test]
fn store_roundtrip_is_bitwise_across_shapes() {
    for (n, dims, shard_rows) in [(1usize, 2usize, 1usize), (5, 3, 2), (23, 4, 7), (64, 2, 64)] {
        let mut rng = gparml::util::rng::Rng::new((n * dims) as u64);
        let data = Matrix::from_fn(n, dims, |_, _| rng.normal());
        let dir = std::env::temp_dir().join(format!(
            "gpds_it_rt_{}_{n}x{dims}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let mut w = StoreWriter::create(&dir, 1, shard_rows, None).unwrap();
        w.append(&data).unwrap();
        let man = w.finish().unwrap();
        assert_eq!(man.n, n);
        assert_eq!(man.dims, dims);
        assert_eq!(man.shards.len(), (n + shard_rows - 1) / shard_rows);

        let src = ShardedDiskSource::open(&dir).unwrap();
        let bytes = src.verify().unwrap();
        assert!(bytes > (n * dims * 8) as u64, "verify must count payload + framing");
        let back = src.read_all().unwrap();
        for (a, b) in data.data().iter().zip(back.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{n}x{dims} shard_rows={shard_rows}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
