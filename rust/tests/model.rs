//! End-to-end tests of the train/serve split (DESIGN.md §9): the
//! exported `TrainedModel` artifact round-trips bit-for-bit, the
//! cluster-free `Predictor` reproduces `Trainer::predict` exactly, the
//! posterior cache changes round counts but never bits, checkpoints
//! resume, a multi-client TCP serve round-trip matches the local path,
//! and post-decommission gathers stay addressable by original row.

use std::net::TcpListener;
use std::path::PathBuf;

use gparml::coordinator::{partition, GlobalOpt, ModelKind, TrainConfig, Trainer};
use gparml::gp::GlobalParams;
use gparml::linalg::Matrix;
use gparml::model::{serve, Checkpoint, PredictScratch, Predictor, TrainedModel};
use gparml::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("gparml_model_{}_{name}", std::process::id()))
}

fn regression_data(n: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let xmu = Matrix::from_fn(n, 2, |_, _| rng.range(-2.0, 2.0));
    let xvar = Matrix::zeros(n, 2);
    let y = Matrix::from_fn(n, 3, |i, j| {
        let x = xmu[(i, 0)];
        let f = match j {
            0 => x.sin(),
            1 => (1.3 * x).cos(),
            _ => 0.5 * x,
        };
        f + 0.05 * rng.normal()
    });
    (xmu, xvar, y)
}

fn init_params(seed: u64) -> GlobalParams {
    let mut rng = Rng::new(seed);
    GlobalParams {
        z: Matrix::from_fn(8, 2, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    }
}

fn config(workers: usize) -> TrainConfig {
    TrainConfig {
        artifact: "test".into(),
        artifacts_dir: artifacts_dir(),
        workers,
        model: ModelKind::Regression,
        global_opt: GlobalOpt::Scg,
        seed: 1,
        ..Default::default()
    }
}

/// A trained trainer + a deterministic test batch.
fn trained(seed: u64, iters: usize) -> (Trainer, Matrix, Matrix) {
    let (xmu, xvar, y) = regression_data(60, seed);
    let shards = partition(&xmu, &xvar, &y, 0.0, 2);
    let mut t = Trainer::new(config(2), init_params(seed + 1), shards).unwrap();
    t.train(iters).unwrap();
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let xt_mu = Matrix::from_fn(11, 2, |_, _| rng.range(-2.0, 2.0));
    let xt_var = Matrix::zeros(11, 2);
    (t, xt_mu, xt_var)
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: diverged at {i}: {x} vs {y}");
    }
}

/// The acceptance criterion: export → save → load → Predictor gives
/// predictions bit-identical (strict mode) to `Trainer::predict` at
/// the same parameters, with zero training workers on the serve side.
#[test]
fn exported_predictor_matches_trainer_predict_bitwise() {
    let (mut t, xt_mu, xt_var) = trained(3, 5);
    let (mean_t, var_t) = t.predict(&xt_mu, &xt_var).unwrap();

    let model = t.export_model().unwrap();
    let path = tmp_path("roundtrip.gpm");
    model.save(&path).unwrap();
    let loaded = TrainedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    // the trainer (and its whole cluster) is gone from here on
    drop(t);
    let pred = Predictor::new(&loaded).unwrap();
    let (mean_p, var_p) = pred.predict(&xt_mu, &xt_var).unwrap();
    assert_bits_eq(mean_t.data(), mean_p.data(), "mean");
    assert_bits_eq(&var_t, &var_p, "var");

    // and the allocation-free entry gives the same bits again
    let mut scratch = PredictScratch::new();
    let mut mean = Matrix::zeros(0, 0);
    let mut var = Vec::new();
    pred.predict_into(&xt_mu, &xt_var, &mut scratch, &mut mean, &mut var)
        .unwrap();
    assert_bits_eq(mean_p.data(), mean.data(), "predict_into mean");
    assert_bits_eq(&var_p, &var, "predict_into var");

    // provenance survived the round-trip
    assert_eq!(loaded.meta.artifact, "test");
    assert_eq!(loaded.meta.iterations, 5);
    assert_eq!(loaded.meta.seed, 1);
    assert!(loaded.meta.final_bound.is_finite());
}

/// Corrupt, truncated and wrong-version model files must be rejected
/// with clear errors — never loaded into a predictor.
#[test]
fn damaged_model_files_are_rejected() {
    let (mut t, _, _) = trained(5, 2);
    let bytes = t.export_model().unwrap().to_bytes().unwrap();

    // truncation at every prefix length
    for cut in [0, 5, 10, 11, bytes.len() / 2, bytes.len() - 1] {
        let err = TrainedModel::from_bytes(&bytes[..cut]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("truncated") || msg.contains("magic"),
            "cut {cut}: {msg}"
        );
    }
    // single flipped payload byte -> checksum failure
    let mut bad = bytes.clone();
    let mid = 11 + (bad.len() - 19) / 2;
    bad[mid] ^= 0x10;
    let msg = format!("{:#}", TrainedModel::from_bytes(&bad).unwrap_err());
    assert!(msg.contains("checksum") || msg.contains("corrupt"), "{msg}");
    // wrong format version
    let mut v = bytes.clone();
    v[4] = 0x7F;
    let msg = format!("{:#}", TrainedModel::from_bytes(&v).unwrap_err());
    assert!(msg.contains("version"), "{msg}");
    // a checkpoint is not a model
    let msg = format!("{:#}", Checkpoint::from_bytes(&bytes).unwrap_err());
    assert!(msg.contains("kind"), "{msg}");
}

/// Satellite: `Trainer::predict` no longer pays a cluster statistics
/// round per call — the posterior is cached by `eval_version`,
/// invalidated by steps, and the results are bitwise identical to an
/// uncached trainer's.
#[test]
fn posterior_cache_is_bitwise_invisible_and_counts_hits() {
    let build = |iters: usize| {
        let (xmu, xvar, y) = regression_data(50, 12);
        let shards = partition(&xmu, &xvar, &y, 0.0, 2);
        let mut t = Trainer::new(config(2), init_params(13), shards).unwrap();
        t.train(iters).unwrap();
        t
    };
    let mut rng = Rng::new(14);
    let xt = Matrix::from_fn(9, 2, |_, _| rng.range(-2.0, 2.0));
    let xt_var = Matrix::zeros(9, 2);

    let mut t = build(3);
    assert_eq!(t.posterior_cache_hits(), 0);
    let (mean_a, var_a) = t.predict(&xt, &xt_var).unwrap();
    let (mean_b, var_b) = t.predict(&xt, &xt_var).unwrap();
    let model = t.export_model().unwrap();
    // the 2nd predict and the export were served from the cache
    assert!(
        t.posterior_cache_hits() >= 2,
        "cache never hit: {}",
        t.posterior_cache_hits()
    );
    assert_bits_eq(mean_a.data(), mean_b.data(), "repeat predict mean");
    assert_bits_eq(&var_a, &var_b, "repeat predict var");

    // a fresh trainer with an identical trajectory agrees bit-for-bit
    // (the cache changed round counts, not numbers)
    let mut fresh = build(3);
    let (mean_f, var_f) = fresh.predict(&xt, &xt_var).unwrap();
    assert_bits_eq(mean_a.data(), mean_f.data(), "cached vs fresh mean");
    assert_bits_eq(&var_a, &var_f, "cached vs fresh var");

    // stepping invalidates: the cached weights must NOT be reused
    t.step().unwrap();
    fresh.step().unwrap();
    let (mean_s, var_s) = t.predict(&xt, &xt_var).unwrap();
    let (mean_fs, var_fs) = fresh.predict(&xt, &xt_var).unwrap();
    assert_bits_eq(mean_s.data(), mean_fs.data(), "post-step mean");
    assert_bits_eq(&var_s, &var_fs, "post-step var");
    assert!(
        mean_s.max_abs_diff(&mean_a) > 0.0,
        "parameters moved but predictions did not — stale posterior cache"
    );

    // decommission (re-shard) also invalidates; the re-sharded
    // posterior agrees to reduce-order precision (rows now sum in a
    // different within-worker order, so bitwise equality is not the
    // contract here — same tolerance as `decommission_preserves_exactness`)
    t.decommission(0).unwrap();
    let (mean_d, _) = t.predict(&xt, &xt_var).unwrap();
    assert!(
        mean_d.max_abs_diff(&mean_s) < 1e-9,
        "decommission moved the posterior: {}",
        mean_d.max_abs_diff(&mean_s)
    );

    // the exported model's weights are the cached ones
    let pred = Predictor::new(&model).unwrap();
    let (mean_m, var_m) = pred.predict(&xt, &xt_var).unwrap();
    assert_bits_eq(mean_a.data(), mean_m.data(), "export used cached weights");
    assert_bits_eq(&var_a, &var_m, "export used cached weights (var)");
}

/// Checkpoint save/resume: restoring mid-training parameters into a
/// fresh cluster resumes at exactly the saved point.
#[test]
fn checkpoint_roundtrip_resumes_training() {
    let path = tmp_path("ckpt.gpc");
    let (xmu, xvar, y) = regression_data(48, 21);

    let mut t = Trainer::new(
        config(2),
        init_params(22),
        partition(&xmu, &xvar, &y, 0.0, 2),
    )
    .unwrap();
    t.train(4).unwrap();
    t.save_checkpoint(&path).unwrap();
    let f_saved = t.evaluate().unwrap();

    // a brand-new cluster (different init!) restored from the file
    // evaluates to the identical bound
    let mut t2 = Trainer::new(
        config(2),
        init_params(99),
        partition(&xmu, &xvar, &y, 0.0, 2),
    )
    .unwrap();
    let done = t2.restore_checkpoint(&path).unwrap();
    assert_eq!(done, 4);
    let f_restored = t2.evaluate().unwrap();
    assert_eq!(
        f_saved.to_bits(),
        f_restored.to_bits(),
        "restored parameters do not reproduce the saved bound: {f_saved} vs {f_restored}"
    );
    // and training continues from there
    let f_more = t2.train(3).unwrap();
    assert!(f_more.is_finite() && f_more >= f_restored - 1e-6);

    // shape/artifact mismatches are rejected loudly
    let mut wrong = config(2);
    wrong.artifact = "small".into();
    let mut rng = Rng::new(1);
    let p16 = GlobalParams {
        z: Matrix::from_fn(16, 2, |_, _| rng.range(-2.0, 2.0)),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.0,
    };
    let mut t3 = Trainer::new(wrong, p16, partition(&xmu, &xvar, &y, 0.0, 2)).unwrap();
    let msg = format!("{:#}", t3.restore_checkpoint(&path).unwrap_err());
    assert!(msg.contains("artifact"), "{msg}");

    std::fs::remove_file(&path).ok();
}

/// The full serve story: one TCP predict server, two concurrent
/// clients, everything bit-identical to the local predictor — and no
/// training cluster anywhere.
#[test]
fn serve_round_trip_with_two_concurrent_clients_is_bitwise() {
    let (mut t, xt_mu, xt_var) = trained(31, 4);
    let model = t.export_model().unwrap();
    drop(t);
    let pred = Predictor::new(&model).unwrap();
    let (mean_local, var_local) = pred.predict(&xt_mu, &xt_var).unwrap();
    let state = serve::ServeState::new(pred);
    let opts = serve::ServeOptions {
        max_clients: 2,
        ..Default::default()
    };

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    std::thread::scope(|s| {
        let server = s.spawn(|| serve::serve(&listener, &state, &opts).unwrap());
        let clients: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                let xt_mu = &xt_mu;
                let xt_var = &xt_var;
                s.spawn(move || {
                    let mut client = serve::ServeClient::connect(&addr).unwrap();
                    let info = client.model_info().unwrap();
                    assert_eq!((info.m, info.q, info.d), (8, 2, 3));
                    assert_eq!(info.version, 1, "fresh server must report version 1");
                    let out = client.predict(xt_mu, xt_var).unwrap();
                    client.hangup();
                    out
                })
            })
            .collect();
        for c in clients {
            let (mean_r, var_r) = c.join().unwrap();
            assert_bits_eq(mean_local.data(), mean_r.data(), "remote mean");
            assert_bits_eq(&var_local, &var_r, "remote var");
        }
        let stats = server.join().unwrap();
        assert_eq!(stats.clients, 2);
        assert_eq!(stats.requests, 4, "2 ModelInfo + 2 ServePredict");
    });
}

/// Satellite: post-decommission gathers return original row indices,
/// so callers can scatter rows back to dataset order instead of
/// tripping over the survivors'-tail permutation.
#[test]
fn gather_locals_indices_survive_decommission() {
    let (xmu, xvar, y) = regression_data(30, 41);
    let shards = partition(&xmu, &xvar, &y, 0.0, 3);
    let mut t = Trainer::new(config(3), init_params(42), shards).unwrap();

    // before: contiguous worker-order indices
    let before = t.gather_locals().unwrap();
    assert_eq!(before.len(), 3);
    let flat: Vec<usize> = before.iter().flat_map(|(ids, _, _)| ids.clone()).collect();
    assert_eq!(flat, (0..30).collect::<Vec<_>>());

    // after decommissioning worker 1 its rows sit at the survivors'
    // tails — the indices must still address the original rows exactly
    t.decommission(1).unwrap();
    let after = t.gather_locals().unwrap();
    assert_eq!(after.len(), 2, "only survivors gather");
    let mut seen = vec![false; 30];
    for (ids, mu, _) in &after {
        assert_eq!(ids.len(), mu.rows());
        for (i, &orig) in ids.iter().enumerate() {
            assert!(!seen[orig], "row {orig} gathered twice");
            seen[orig] = true;
            // regression model: locals never move, so each gathered row
            // must equal the original dataset row bit-for-bit
            assert_bits_eq(mu.row(i), xmu.row(orig), "relocated row content");
        }
    }
    assert!(seen.iter().all(|s| *s), "a row went missing in the re-shard");

    // the moved rows are NOT in contiguous order anymore (the footgun
    // the indices exist to defuse): the concatenated order must differ
    // from 0..n while the index set is complete
    let flat_after: Vec<usize> = after.iter().flat_map(|(ids, _, _)| ids.clone()).collect();
    assert_ne!(
        flat_after,
        (0..30).collect::<Vec<_>>(),
        "decommission unexpectedly preserved contiguity — the test lost its teeth"
    );
}

/// The Predictor is shared across threads by reference (Send + Sync):
/// hammering one instance from several threads yields bit-identical
/// results per thread.
#[test]
fn predictor_is_shared_across_threads_bitwise() {
    let (mut t, xt_mu, xt_var) = trained(51, 3);
    let model = t.export_model().unwrap();
    drop(t);
    let pred = Predictor::new(&model).unwrap();
    let (mean_ref, var_ref) = pred.predict(&xt_mu, &xt_var).unwrap();

    std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let pred = &pred;
                let xt_mu = &xt_mu;
                let xt_var = &xt_var;
                s.spawn(move || {
                    let mut scratch = PredictScratch::new();
                    let mut mean = Matrix::zeros(0, 0);
                    let mut var = Vec::new();
                    for _ in 0..5 {
                        pred.predict_into(xt_mu, xt_var, &mut scratch, &mut mean, &mut var)
                            .unwrap();
                    }
                    (mean, var)
                })
            })
            .collect();
        for h in handles {
            let (mean, var) = h.join().unwrap();
            assert_bits_eq(mean_ref.data(), mean.data(), "threaded mean");
            assert_bits_eq(&var_ref, &var, "threaded var");
        }
    });
}
