//! PJRT runtime integration: the compiled HLO artifacts, executed via
//! [`gparml::runtime::ShardExecutor`], must agree with (a) the native
//! Rust mirrors and (b) the recorded JAX oracle totals — proving the
//! three layers compose with no Python on the execution path.

use std::path::Path;

use gparml::gp::{self, kernel, GlobalParams, Stats};
use gparml::linalg::Matrix;
use gparml::runtime::{Manifest, ShardData, ShardExecutor};
use gparml::util::json::Json;
use gparml::util::rng::Rng;

fn manifest() -> Manifest {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    Manifest::load(&dir).expect("run `make artifacts` first")
}

fn mat(j: &Json, rows: usize, cols: usize) -> Matrix {
    Matrix::from_vec(rows, cols, j.as_f64_flat().unwrap())
}

/// Load the testvector cases whose shapes match the `test` artifact
/// config (m=8, q=2, d=3).
fn artifact_cases() -> Vec<Json> {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/testvectors.json");
    let doc = Json::from_file(&path).unwrap();
    doc.get("cases")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|c| c.get("m").unwrap().as_usize().unwrap() == 8)
        .cloned()
        .collect()
}

fn case_inputs(c: &Json) -> (GlobalParams, ShardData, Vec<f64>, usize) {
    let b = c.get("B").unwrap().as_usize().unwrap();
    let m = c.get("m").unwrap().as_usize().unwrap();
    let q = c.get("q").unwrap().as_usize().unwrap();
    let d = c.get("d").unwrap().as_usize().unwrap();
    let inputs = c.get("inputs").unwrap();
    let params = GlobalParams {
        z: mat(inputs.get("Z").unwrap(), m, q),
        log_ls: inputs.get("log_ls").unwrap().as_f64_flat().unwrap(),
        log_sf2: inputs.get("log_sf2").unwrap().as_f64().unwrap(),
        log_beta: inputs.get("log_beta").unwrap().as_f64().unwrap(),
    };
    let shard = ShardData {
        xmu: mat(inputs.get("Xmu").unwrap(), b, q),
        xvar: mat(inputs.get("Xvar").unwrap(), b, q),
        y: mat(inputs.get("Y").unwrap(), b, d),
        kl_weight: c.get("kl_weight").unwrap().as_f64().unwrap(),
    };
    let mask = inputs.get("mask").unwrap().as_f64_flat().unwrap();
    (params, shard, mask, d)
}

/// Drop the masked-out rows (the oracle uses a random mask; the executor
/// only masks padding, so bake the oracle mask in by filtering rows).
fn filter_shard(shard: &ShardData, mask: &[f64], q: usize, d: usize) -> (ShardData, Vec<usize>) {
    let live: Vec<usize> = mask
        .iter()
        .enumerate()
        .filter(|(_, &m)| m > 0.0)
        .map(|(i, _)| i)
        .collect();
    let filter =
        |src: &Matrix, cols: usize| Matrix::from_fn(live.len(), cols, |r, j| src[(live[r], j)]);
    (
        ShardData {
            xmu: filter(&shard.xmu, q),
            xvar: filter(&shard.xvar, q),
            y: filter(&shard.y, d),
            kl_weight: shard.kl_weight,
        },
        live,
    )
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn artifact_stats_match_oracle_stats() {
    let exec = ShardExecutor::new(&manifest(), "test").unwrap();
    for c in artifact_cases() {
        let (params, shard, mask, d) = case_inputs(&c);
        let (fshard, _) = filter_shard(&shard, &mask, params.q(), d);
        let st = exec.shard_stats(&params, &fshard).unwrap();
        let stats_j = c.get("stats").unwrap();
        let name = c.get("name").unwrap().as_str().unwrap();
        assert!(
            close(st.a, stats_j.get("a").unwrap().as_f64().unwrap(), 1e-11),
            "{name}: a"
        );
        assert!(
            close(st.psi0, stats_j.get("psi0").unwrap().as_f64().unwrap(), 1e-11),
            "{name}: psi0"
        );
        assert!(
            close(st.kl, stats_j.get("kl").unwrap().as_f64().unwrap(), 1e-11),
            "{name}: kl"
        );
        let m = params.m();
        let c_exp = mat(stats_j.get("C").unwrap(), m, d);
        let d_exp = mat(stats_j.get("D").unwrap(), m, m);
        assert!(st.c.max_abs_diff(&c_exp) < 1e-10, "{name}: C");
        assert!(st.d.max_abs_diff(&d_exp) < 1e-10, "{name}: D");
    }
}

#[test]
fn full_distributed_gradient_matches_jax_monolithic() {
    // The complete two-round protocol on one shard:
    //   stats (artifact) -> bound + adjoints (native) ->
    //   shard_grads + kmm_grads (artifacts) -> totals == jax.grad totals.
    let exec = ShardExecutor::new(&manifest(), "test").unwrap();
    for c in artifact_cases() {
        let (params, shard, mask, dout) = case_inputs(&c);
        let name = c.get("name").unwrap().as_str().unwrap();
        let (fshard, live) = filter_shard(&shard, &mask, params.q(), dout);

        let stats = exec.shard_stats(&params, &fshard).unwrap();
        let jitter = c.get("jitter").unwrap().as_f64().unwrap();
        let kmm = kernel::kmm(&params, jitter);
        let (_bv, adj) = gp::assemble_bound(&stats, &kmm, params.log_beta, dout).unwrap();

        let (mut total, local) = exec.shard_grads(&params, &fshard, &adj).unwrap();
        let (kmm_art, central) = exec.kmm_grads(&params, &adj.d_kmm).unwrap();
        assert!(
            kmm_art.add_diag(jitter).max_abs_diff(&kmm) < 1e-11,
            "{name}: artifact Kmm"
        );
        total.accumulate(&central);

        let grads = c.get("grads").unwrap();
        let (m, q) = (params.m(), params.q());
        let dz_exp = mat(grads.get("Z").unwrap(), m, q);
        assert!(
            total.d_z.max_abs_diff(&dz_exp) < 1e-7 * (1.0 + dz_exp.max_abs()),
            "{name}: dZ, max diff {}",
            total.d_z.max_abs_diff(&dz_exp)
        );
        let dls_exp = grads.get("log_ls").unwrap().as_f64_flat().unwrap();
        for (a, e) in total.d_log_ls.iter().zip(&dls_exp) {
            assert!(close(*a, *e, 1e-7), "{name}: dlog_ls {a} vs {e}");
        }
        assert!(
            close(
                total.d_log_sf2,
                grads.get("log_sf2").unwrap().as_f64().unwrap(),
                1e-7
            ),
            "{name}: dlog_sf2"
        );
        assert!(
            close(
                adj.d_log_beta,
                grads.get("log_beta").unwrap().as_f64().unwrap(),
                1e-8
            ),
            "{name}: dlog_beta"
        );

        // local gradients: oracle rows are indexed by the original layout
        let b = c.get("B").unwrap().as_usize().unwrap();
        let dxmu_exp = mat(grads.get("Xmu").unwrap(), b, q);
        let scale = 1.0 + dxmu_exp.max_abs();
        for (r, &i) in live.iter().enumerate() {
            for j in 0..q {
                let a = local.d_xmu[(r, j)];
                let e = dxmu_exp[(i, j)];
                assert!(
                    (a - e).abs() < 1e-8 * scale,
                    "{name}: dXmu[{i},{j}] {a} vs {e}"
                );
            }
        }
    }
}

#[test]
fn artifact_predict_matches_native_predict() {
    let exec = ShardExecutor::new(&manifest(), "test").unwrap();
    let mut rng = Rng::new(17);
    let (m, q, d) = (8, 2, 3);
    let params = GlobalParams {
        z: Matrix::from_fn(m, q, |_, _| rng.normal()),
        log_ls: vec![0.1, -0.1],
        log_sf2: 0.0,
        log_beta: 2.0,
    };
    let n = 40;
    let shard = ShardData {
        xmu: Matrix::from_fn(n, q, |_, _| rng.normal()),
        xvar: Matrix::zeros(n, q),
        y: Matrix::from_fn(n, d, |_, _| rng.normal()),
        kl_weight: 0.0,
    };
    let stats = exec.shard_stats(&params, &shard).unwrap();
    let kmm = kernel::kmm(&params, 1e-8);
    let w = gp::bound::posterior_weights(&stats, &kmm, params.log_beta).unwrap();
    let t = 7;
    let xt_mu = Matrix::from_fn(t, q, |_, _| rng.normal());
    let xt_var = Matrix::zeros(t, q);
    let (mean_a, var_a) = exec.predict(&params, &xt_mu, &xt_var, &w.w1, &w.wv).unwrap();
    let (mean_n, var_n) = gp::bound::predict_native(&params, &w, &xt_mu, &xt_var);
    assert!(mean_a.max_abs_diff(&mean_n) < 1e-10);
    for (a, b) in var_a.iter().zip(&var_n) {
        assert!((a - b).abs() < 1e-10);
    }
}

#[test]
fn executor_chunks_large_shards_identically() {
    // A shard larger than the artifact capacity B must produce the same
    // statistics as the native path (chunk + pad + mask correctness).
    let exec = ShardExecutor::new(&manifest(), "test").unwrap();
    let mut rng = Rng::new(23);
    let (m, q, d) = (8, 2, 3);
    let params = GlobalParams {
        z: Matrix::from_fn(m, q, |_, _| rng.normal()),
        log_ls: vec![0.0, 0.2],
        log_sf2: 0.1,
        log_beta: 1.0,
    };
    let n = 101; // deliberately not a multiple of B=32
    let shard = ShardData {
        xmu: Matrix::from_fn(n, q, |_, _| rng.normal()),
        xvar: Matrix::from_fn(n, q, |_, _| 0.05 + rng.uniform()),
        y: Matrix::from_fn(n, d, |_, _| rng.normal()),
        kl_weight: 1.0,
    };
    let st_art = exec.shard_stats(&params, &shard).unwrap();
    let st_nat = kernel::shard_stats(
        &params,
        &shard.xmu,
        &shard.xvar,
        &shard.y,
        &vec![1.0; n],
        1.0,
    );
    assert!(close(st_art.a, st_nat.a, 1e-11));
    assert!(close(st_art.psi0, st_nat.psi0, 1e-11));
    assert!(close(st_art.kl, st_nat.kl, 1e-11));
    assert!(st_art.c.max_abs_diff(&st_nat.c) < 1e-10);
    assert!(st_art.d.max_abs_diff(&st_nat.d) < 1e-10);
    assert_eq!(st_art.n, n as f64);
}

#[test]
fn stats_reduce_is_shard_partition_invariant() {
    // Splitting the data across "nodes" must not change the accumulated
    // statistics — the core invariant of the paper's reduce step,
    // exercised through the real artifact path.
    let exec = ShardExecutor::new(&manifest(), "test").unwrap();
    let mut rng = Rng::new(29);
    let (m, q, d) = (8, 2, 3);
    let params = GlobalParams {
        z: Matrix::from_fn(m, q, |_, _| rng.normal()),
        log_ls: vec![0.0, 0.0],
        log_sf2: 0.0,
        log_beta: 1.5,
    };
    let n = 60;
    let xmu = Matrix::from_fn(n, q, |_, _| rng.normal());
    let xvar = Matrix::from_fn(n, q, |_, _| 0.1 + rng.uniform());
    let y = Matrix::from_fn(n, d, |_, _| rng.normal());
    let slice = |lo: usize, hi: usize| ShardData {
        xmu: Matrix::from_fn(hi - lo, q, |i, j| xmu[(lo + i, j)]),
        xvar: Matrix::from_fn(hi - lo, q, |i, j| xvar[(lo + i, j)]),
        y: Matrix::from_fn(hi - lo, d, |i, j| y[(lo + i, j)]),
        kl_weight: 1.0,
    };
    let whole = exec.shard_stats(&params, &slice(0, n)).unwrap();
    for splits in [vec![0, 20, 40, n], vec![0, 7, 13, 44, n]] {
        let mut acc = Stats::zeros(m, d);
        for w in splits.windows(2) {
            acc.accumulate(&exec.shard_stats(&params, &slice(w[0], w[1])).unwrap());
        }
        assert!(close(acc.a, whole.a, 1e-12));
        assert!(acc.c.max_abs_diff(&whole.c) < 1e-11);
        assert!(acc.d.max_abs_diff(&whole.d) < 1e-11);
        assert!(close(acc.kl, whole.kl, 1e-12));
    }
}
