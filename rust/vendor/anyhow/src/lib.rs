//! Vendored, dependency-free subset of the `anyhow` crate API
//! (DESIGN.md §5: the build must be hermetic — no crates.io access).
//!
//! Implements the surface this repository actually uses:
//! [`Error`], [`Result`], the [`Context`] trait (`context` /
//! `with_context` on `Result` and `Option`), and the `anyhow!`,
//! `bail!`, `ensure!` macros. Error chains render like anyhow's:
//! `{}` shows the outermost message, `{:#}` joins the chain with ": ".

use std::fmt;

/// An error message chain: the outermost context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            chain: vec![m.to_string()],
        }
    }

    /// Wrap `self` in one more layer of context.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does not implement `std::error::Error`, so
// this blanket conversion does not overlap with `impl From<T> for T`
// (the same trick the real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        // no format! here: stringified conditions may contain braces
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_formats_match_anyhow_conventions() {
        let e: Error = Error::from(io_err()).context("reading manifest.json");
        assert_eq!(format!("{e}"), "reading manifest.json");
        assert_eq!(format!("{e:#}"), "reading manifest.json: file missing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: file missing");
        let o: Option<u8> = None;
        assert_eq!(format!("{:#}", o.context("missing key").unwrap_err()), "missing key");
    }

    #[test]
    fn macros_build_and_return_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 10 {
                bail!("too big: {}", x);
            }
            ensure!(x != 5);
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{:#}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{:#}", f(11).unwrap_err()), "too big: 11");
        assert!(format!("{:#}", f(5).unwrap_err()).contains("x != 5"));
        let e = anyhow!("plain message");
        assert_eq!(e.root_cause(), "plain message");
    }
}
