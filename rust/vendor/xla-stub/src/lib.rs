//! Stub of the `xla` (PJRT) crate API surface used by `gparml`.
//!
//! The real crate links the PJRT C API and cannot be vendored here
//! (DESIGN.md §5: offline, hermetic builds). This stub keeps the
//! `pjrt` feature *compiling* so the whole workspace can be
//! type-checked/clippy'd with `--all-features`; every operation fails
//! at runtime with a clear message. Swap the `xla` path dependency in
//! `rust/Cargo.toml` for the real crate to run the artifact path.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "PJRT unavailable: {what} called on the stub `xla` crate; replace \
         rust/vendor/xla-stub with the real xla crate to enable --features pjrt"
    ))
}

#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}
